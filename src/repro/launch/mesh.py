"""Production mesh builders.

Functions, not module-level constants: importing this module never touches
jax device state.  The single-pod mesh is (data=16, model=16) = 256 chips;
the multi-pod mesh adds a leading pod axis: (pod=2, data=16, model=16) = 512.

The ``pod`` axis doubles as the Raptor *flight* axis: a serving invocation
flown at concurrency 2 runs one member per pod (DESIGN.md §2).
"""
from __future__ import annotations

import jax


def make_mesh(shape, axes):
    """jax.make_mesh across jax versions: ``axis_types`` (and the
    ``jax.sharding.AxisType`` enum) only exist on newer releases; older ones
    default every axis to auto sharding anyway, so simply omit the kwarg."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_config_mesh(devices=None):
    """1-D ``("config",)`` mesh — the sweep driver's multi-controller axis.

    ``sim/sweeps.py`` shards config-grid sweeps over this mesh; on CPU-only
    hosts the devices come from ``--xla_force_host_platform_device_count``
    (``sim.sweeps.force_host_devices``), so the same code path runs on a
    multi-chip pod and a GitHub runner.  Built from an explicit device list
    (``jax.make_mesh`` has no devices knob on older releases).
    """
    import numpy as np
    devs = list(devices) if devices is not None else jax.devices()
    return jax.sharding.Mesh(np.asarray(devs), ("config",))


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (CPU tests / examples)."""
    n = len(jax.devices())
    data = min(data, n)
    model = max(1, min(model, n // data))
    return make_mesh((data, model), ("data", "model"))


def batch_axes(mesh) -> tuple:
    """Mesh axes that shard the batch dimension."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def tp_size(mesh) -> int:
    return mesh.shape.get("model", 1)


def dp_size(mesh) -> int:
    out = 1
    for a in batch_axes(mesh):
        out *= mesh.shape[a]
    return out
