import os
if "--xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    # append, never overwrite: a user-supplied XLA_FLAGS (tuning flags,
    # dump dirs) must survive; an explicit device count wins outright
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count"
                                 "=512").strip()

"""Multi-pod dry-run: lower + compile every (architecture x input shape) cell
on the production meshes, proving the distribution config is coherent.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun                 # all cells
    PYTHONPATH=src python -m repro.launch.dryrun --arch gemma-2b
    PYTHONPATH=src python -m repro.launch.dryrun --arch gemma-2b \
        --shape train_4k --multi-pod --json out.json

The very first lines above force 512 host devices BEFORE any jax import —
jax locks the device count at first init (see system notes).  Do not move
them, and do not replicate this env var anywhere global.
"""
import argparse
import json
import re
import sys
import time
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs import applicable_shapes, ARCH_NAMES, get_config, shape_by_name
from repro.configs.base import ModelConfig, ShapeConfig
from repro.distributed.sharding import Plan
from repro.launch import specs as S
from repro.launch.mesh import batch_axes, make_production_mesh
from repro.models.moe import EPSpec
from repro.serving.step import cache_shape, make_decode_step, make_prefill_step
from repro.training.optimizer import OptConfig
from repro.training.step import make_train_step, train_state_shape

COLLECTIVE_RE = re.compile(
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
)


def _opt_config(cfg: ModelConfig) -> OptConfig:
    return OptConfig(state_dtype=cfg.optimizer_state_dtype)


def lower_cell(cfg: ModelConfig, shape: ShapeConfig, mesh):
    """Build + lower one (arch, shape) cell on a mesh.  Returns lowered."""
    plan = Plan(mesh, cfg)
    ep = (EPSpec(mesh, batch_axes(mesh)) if cfg.moe is not None else None)
    if shape.kind == "train":
        oc = _opt_config(cfg)
        step = make_train_step(cfg, oc, constrain=plan.constrain, ep=ep)
        state_shape = train_state_shape(cfg, oc)
        state_sh = {
            "params": plan.param_shardings(state_shape["params"]),
            "opt": {
                "mu": plan.param_shardings(state_shape["opt"]["mu"]),
                "nu": plan.param_shardings(state_shape["opt"]["nu"]),
                "step": jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec()),
            },
        }
        batch_shape = S.train_batch_specs(cfg, shape)
        batch_sh = plan.batch_shardings(batch_shape)
        fn = jax.jit(step, in_shardings=(state_sh, batch_sh),
                     donate_argnums=(0,))
        return fn.lower(state_shape, batch_shape)

    params_shape = jax.eval_shape(
        lambda: __import__("repro.models", fromlist=["init_params"]).init_params(
            cfg, jax.random.key(0)))
    params_sh = plan.param_shardings(params_shape)

    if shape.kind == "prefill":
        step = make_prefill_step(cfg, max_len=shape.seq_len,
                                 constrain=plan.constrain, ep=ep)
        batch_shape = S.prefill_batch_specs(cfg, shape)
        batch_sh = plan.batch_shardings(batch_shape)
        fn = jax.jit(step, in_shardings=(params_sh, batch_sh))
        return fn.lower(params_shape, batch_shape)

    # decode
    step = make_decode_step(cfg, constrain=plan.constrain, ep=ep)
    cache = cache_shape(cfg, shape.global_batch, shape.seq_len,
                        enc_len=S.enc_len_for(cfg, shape))
    cache_sh = plan.cache_shardings(cache)
    tok = S.decode_token_specs(cfg, shape)
    tok_sh = plan.batch_shardings(tok)
    fn = jax.jit(step, in_shardings=(params_sh, cache_sh, tok_sh),
                 donate_argnums=(1,))
    return fn.lower(params_shape, cache, tok)


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Sum per-device operand bytes of collective ops in SPMD HLO, with ring
    cost factors applied later (benchmarks/roofline.py)."""
    out: Dict[str, float] = {}
    # lines look like: %all-reduce.5 = bf16[1024,512]{1,0} all-reduce(...)
    for m in re.finditer(
            r"= *([a-z0-9_]+)\[([0-9,]*)\][^ ]* (all-gather|all-reduce|"
            r"reduce-scatter|all-to-all|collective-permute)", hlo_text):
        dtype_s, dims_s, op = m.groups()
        bits = {"f32": 32, "bf16": 16, "f16": 16, "s32": 32, "u32": 32,
                "s8": 8, "u8": 8, "pred": 8, "f64": 64, "s64": 64,
                "u64": 64, "s16": 16, "u16": 16}.get(dtype_s, 32)
        n = 1
        if dims_s:
            for d in dims_s.split(","):
                n *= int(d)
        out[op] = out.get(op, 0.0) + n * bits / 8
    return out


def analyze(lowered, compile_also: bool = True) -> Dict[str, Any]:
    info: Dict[str, Any] = {}
    t0 = time.time()
    compiled = lowered.compile()
    info["compile_s"] = round(time.time() - t0, 1)
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    info["flops_per_device"] = float(ca.get("flops", 0.0))
    info["bytes_per_device"] = float(ca.get("bytes accessed", 0.0))
    ma = compiled.memory_analysis()
    info["arg_bytes"] = int(ma.argument_size_in_bytes)
    info["temp_bytes"] = int(ma.temp_size_in_bytes)
    info["out_bytes"] = int(ma.output_size_in_bytes)
    info["peak_bytes_per_device"] = (info["arg_bytes"] + info["temp_bytes"]
                                     + info["out_bytes"])
    hlo = compiled.as_text()
    info["collective_bytes"] = collective_bytes(hlo)
    info["n_collectives"] = len(COLLECTIVE_RE.findall(hlo))
    return info


def run_cell(arch: str, shape_name: str, multi_pod: bool) -> Dict[str, Any]:
    cfg = get_config(arch)
    shape = shape_by_name(shape_name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    rec: Dict[str, Any] = {"arch": arch, "shape": shape_name,
                           "mesh": "x".join(str(s) for s in
                                            tuple(mesh.shape.values()))}
    t0 = time.time()
    with mesh:
        lowered = lower_cell(cfg, shape, mesh)
        rec["lower_s"] = round(time.time() - t0, 1)
        rec.update(analyze(lowered))
    rec["ok"] = True
    return rec


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=list(ARCH_NAMES))
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--json", default=None)
    args = ap.parse_args()

    archs = [args.arch] if args.arch else list(ARCH_NAMES)
    results = []
    failures = 0
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    for arch in archs:
        cfg = get_config(arch)
        shapes = ([shape_by_name(args.shape)] if args.shape
                  else applicable_shapes(cfg))
        for shape in shapes:
            for mp in meshes:
                tag = f"{arch} x {shape.name} x {'2x16x16' if mp else '16x16'}"
                try:
                    rec = run_cell(arch, shape.name, mp)
                    print(f"[ok] {tag}: lower={rec['lower_s']}s "
                          f"compile={rec['compile_s']}s "
                          f"flops/dev={rec['flops_per_device']:.3e} "
                          f"peak={rec['peak_bytes_per_device']/2**30:.2f}GiB "
                          f"colls={rec['n_collectives']}")
                except Exception as e:  # noqa: BLE001 - report and continue
                    failures += 1
                    rec = {"arch": arch, "shape": shape.name,
                           "multi_pod": mp, "ok": False, "error": repr(e)[:500]}
                    print(f"[FAIL] {tag}: {repr(e)[:300]}")
                results.append(rec)
                sys.stdout.flush()
    if args.json:
        with open(args.json, "w") as f:
            json.dump(results, f, indent=1)
    print(f"done: {len(results) - failures}/{len(results)} cells ok")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
