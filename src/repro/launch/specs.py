"""ShapeDtypeStruct stand-ins for every model input of every (arch x shape)
cell — the same pattern the dry-run, roofline and benchmarks all read from.
No device allocation happens here."""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig

ENC_RATIO = 4  # audio frames per decoder token ratio for enc-dec shapes


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))


def train_batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    b, s = shape.global_batch, shape.seq_len
    batch: Dict[str, Any] = {"labels": sds((b, s), "int32")}
    if cfg.embedding_inputs:
        batch["embeddings"] = sds((b, s, cfg.d_model), cfg.dtype)
    else:
        batch["tokens"] = sds((b, s), "int32")
    if cfg.mrope:
        batch["positions"] = sds((3, b, s), "int32")
    if cfg.is_encoder_decoder:
        batch["enc_emb"] = sds((b, s // ENC_RATIO, cfg.d_model), cfg.dtype)
    return batch


def prefill_batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    b, s = shape.global_batch, shape.seq_len
    batch: Dict[str, Any] = {}
    if cfg.embedding_inputs:
        batch["embeddings"] = sds((b, s, cfg.d_model), cfg.dtype)
    else:
        batch["tokens"] = sds((b, s), "int32")
    if cfg.mrope:
        batch["positions"] = sds((3, b, s), "int32")
    if cfg.is_encoder_decoder:
        batch["enc_emb"] = sds((b, s // ENC_RATIO, cfg.d_model), cfg.dtype)
    return batch


def decode_token_specs(cfg: ModelConfig, shape: ShapeConfig):
    b = shape.global_batch
    if cfg.embedding_inputs and not cfg.is_encoder_decoder:
        # generated tokens re-enter through the tied embedding table
        return sds((b, 1), "int32")
    return sds((b, 1), "int32")


def enc_len_for(cfg: ModelConfig, shape: ShapeConfig) -> int:
    return shape.seq_len // ENC_RATIO if cfg.is_encoder_decoder else 0
