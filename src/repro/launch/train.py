"""Training launcher: real steps on the host mesh (CPU here, TPU fleet via
the same code path), with checkpoint/resume, Raptor redundant-DP weights,
and preemption-signal checkpointing.

    PYTHONPATH=src python -m repro.launch.train --arch gemma-2b --steps 50 \
        --reduced --ckpt /tmp/ckpt
"""
from __future__ import annotations

import argparse
import dataclasses
import signal
import sys
import time

import jax
import numpy as np

from repro.checkpoint import io as ckpt_io
from repro.configs import get_config, reduced_config
from repro.configs.base import ShapeConfig
from repro.data.synthetic import make_batch
from repro.distributed.collectives import compress_grads
from repro.training.optimizer import OptConfig
from repro.training.raptor_dp import signals_to_weights
from repro.training.step import (StepOptions, init_train_state,
                                 make_train_step)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--reduced", action="store_true",
                    help="tiny same-family config (CPU-sized)")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--grad-compression", default=None,
                    choices=[None, "bf16", "int8"])
    ap.add_argument("--simulate-failure-at", type=int, default=-1,
                    help="kill a flight member's contribution at this step")
    ap.add_argument("--num-pods", type=int, default=2)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced_config(cfg)
    shape = ShapeConfig("host", args.seq, args.batch, "train")
    oc = OptConfig(warmup_steps=5, total_steps=args.steps,
                   state_dtype=cfg.optimizer_state_dtype)

    step_fn = jax.jit(make_train_step(
        cfg, oc, options=StepOptions(remat=False),
        grad_transform=compress_grads(args.grad_compression)))

    state = init_train_state(cfg, oc, jax.random.PRNGKey(0))
    start = 0
    if args.resume and args.ckpt:
        try:
            state, start = ckpt_io.restore(args.ckpt, state)
            start += 1
            print(f"resumed from step {start - 1}")
        except FileNotFoundError:
            print("no checkpoint found; starting fresh")

    stop = {"now": False}
    signal.signal(signal.SIGTERM, lambda *a: stop.update(now=True))

    t0 = time.time()
    for step in range(start, args.steps):
        batch = {k: jax.numpy.asarray(v)
                 for k, v in make_batch(cfg, shape, step).items()}
        # Raptor redundant-DP: per-pod health -> per-sample weights
        health = np.ones(args.num_pods)
        if step == args.simulate_failure_at:
            health[-1] = 0.0
            print(f"step {step}: simulating pod failure "
                  f"(flight degrades, step proceeds)")
        batch["loss_weight"] = jax.numpy.asarray(
            signals_to_weights(args.batch, args.num_pods, health=health))
        state, metrics = step_fn(state, batch)
        if step % 5 == 0 or step == args.steps - 1:
            print(f"step {step}: loss={float(metrics['loss']):.4f} "
                  f"gnorm={float(metrics['grad_norm']):.3f} "
                  f"lr={float(metrics['lr']):.2e}")
        if args.ckpt and (step % args.ckpt_every == 0 or stop["now"]
                          or step == args.steps - 1):
            ckpt_io.save(args.ckpt, step, state)
        if stop["now"]:
            print("SIGTERM: checkpointed and exiting for restart")
            return 0
    dt = time.time() - t0
    print(f"done: {args.steps - start} steps in {dt:.1f}s")
    return 0


if __name__ == "__main__":
    sys.exit(main())
