"""Serving launcher: batched generation with optional Raptor flights, or
the live streaming scheduler service.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b --reduced \
        --flight 2 --requests 4

    PYTHONPATH=src python -m repro.launch.serve --mode scheduler \
        --workload keygen --load high --jobs 4096 --arrival mmpp
"""
from __future__ import annotations

import argparse
import sys

import jax


def _build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=("generate", "scheduler"),
                    default="generate",
                    help="generate: batched model serving; scheduler: the "
                         "open-arrival Raptor scheduling service")
    # -- generate mode -------------------------------------------------
    ap.add_argument("--arch", default="gemma-2b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--decode-steps", type=int, default=8)
    ap.add_argument("--max-len", type=int, default=None,
                    help="KV-cache budget; default prompt+decode+8")
    ap.add_argument("--flight", type=int, default=1)
    ap.add_argument("--jitter-ms", type=float, default=0.0)
    # -- scheduler mode ------------------------------------------------
    ap.add_argument("--workload", default="keygen",
                    choices=("keygen", "wordcount", "thumbnail",
                             "heavytail"))
    ap.add_argument("--load", default="medium")
    ap.add_argument("--workers", type=int, default=15)
    ap.add_argument("--azs", type=int, default=3)
    ap.add_argument("--jobs", type=int, default=4096)
    ap.add_argument("--microbatch", type=int, default=64)
    ap.add_argument("--arrival", default="poisson",
                    choices=("poisson", "mmpp", "diurnal"))
    ap.add_argument("--slo-ms", type=float, default=None)
    ap.add_argument("--seed", type=int, default=0)
    return ap


def _validate(args: argparse.Namespace) -> None:
    """Reject misconfigurations up front with clear ValueErrors (a silent
    negative jitter or an overflowing decode budget corrupts the very
    latency numbers the run exists to measure)."""
    if args.jitter_ms < 0.0:
        raise ValueError(
            f"--jitter-ms must be >= 0, got {args.jitter_ms}")
    if args.prompt_len < 1:
        raise ValueError(f"--prompt-len must be >= 1, got {args.prompt_len}")
    if args.decode_steps < 1:
        raise ValueError(
            f"--decode-steps must be >= 1, got {args.decode_steps}")
    max_len = (args.max_len if args.max_len is not None
               else args.prompt_len + args.decode_steps + 8)
    if args.prompt_len + args.decode_steps > max_len:
        raise ValueError(
            f"--prompt-len {args.prompt_len} + --decode-steps "
            f"{args.decode_steps} overflows --max-len {max_len}")
    args.max_len = max_len
    if args.jobs < 1:
        raise ValueError(f"--jobs must be >= 1, got {args.jobs}")
    if args.microbatch < 1:
        raise ValueError(f"--microbatch must be >= 1, got {args.microbatch}")


def _run_generate(args) -> int:
    from repro.configs import get_config, reduced_config
    from repro.models import init_params
    from repro.serving.engine import (ServeConfig, ServingEngine,
                                      demo_requests)
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced_config(cfg)
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, params, ServeConfig(
        max_len=args.max_len,
        decode_steps=args.decode_steps, flight_size=args.flight,
        mean_jitter_s=args.jitter_ms / 1e3))
    batches = [demo_requests(cfg, args.batch, args.prompt_len, seed=i)
               for i in range(args.requests)]
    stats = eng.serve(batches, raptor=args.flight > 1)
    s = stats.summary()
    print(f"cold compile {s['cold_s']*1e3:.0f} ms, warm ref "
          f"{s['warm_s']*1e3:.0f} ms (excluded from latencies)")
    print(f"{s['requests']} requests: mean {s['mean_s']*1e3:.0f} ms  "
          f"p50 {s['p50_s']*1e3:.0f} ms  p99 {s['p99_s']*1e3:.0f} ms")
    return 0


def _run_scheduler(args) -> int:
    from repro.serving.engine import SchedulerService
    from repro.sim.events import (DiurnalArrivals, MMPPArrivals,
                                  PoissonArrivals)
    from repro.sim.vector_queue import (QueueFlightSim, heavytail_queue,
                                        keygen_queue, thumbnail_queue,
                                        wordcount_queue)
    wl = {"keygen": keygen_queue, "wordcount": wordcount_queue,
          "thumbnail": thumbnail_queue, "heavytail": heavytail_queue}[
              args.workload]()
    sim = QueueFlightSim(wl, num_workers=args.workers, num_azs=args.azs,
                         load=args.load, seed=args.seed)
    proc = {"poisson": lambda: PoissonArrivals(sim.rate_hz, seed=args.seed),
            "mmpp": lambda: MMPPArrivals(sim.rate_hz, seed=args.seed),
            "diurnal": lambda: DiurnalArrivals(sim.rate_hz, seed=args.seed),
            }[args.arrival]()
    svc = SchedulerService(sim, microbatch=args.microbatch, seed=args.seed)
    rep = svc.run_open_load(jobs=args.jobs, microbatch=args.microbatch,
                            slo_ms=args.slo_ms, process=proc,
                            seed=args.seed)
    print(f"{args.workload} @ {args.load} ({args.arrival} arrivals, "
          f"{sim.W} workers/{sim.A} AZs):")
    print(f"  sustained {rep.jobs_per_s:,.0f} jobs/s "
          f"({rep.jobs} jobs in {rep.wall_s*1e3:.0f} ms wall)")
    print(f"  sojourn mean {rep.mean_ms:.0f} ms  p50 {rep.p50_ms:.0f} ms  "
          f"p99 {rep.p99_ms:.0f} ms")
    print(f"  SLO {rep.slo_ms:.0f} ms violated "
          f"{rep.slo_violation_frac*100:.1f}% (ok {rep.ok_frac*100:.1f}%)")
    return 0


def main(argv=None) -> int:
    args = _build_parser().parse_args(argv)
    _validate(args)
    if args.mode == "scheduler":
        return _run_scheduler(args)
    return _run_generate(args)


if __name__ == "__main__":
    sys.exit(main())
