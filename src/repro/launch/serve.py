"""Serving launcher: batched generation with optional Raptor flights.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b --reduced \
        --flight 2 --requests 4
"""
from __future__ import annotations

import argparse
import sys

import jax

from repro.configs import get_config, reduced_config
from repro.models import init_params
from repro.serving.engine import ServeConfig, ServingEngine, demo_requests


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--decode-steps", type=int, default=8)
    ap.add_argument("--flight", type=int, default=1)
    ap.add_argument("--jitter-ms", type=float, default=0.0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced_config(cfg)
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, params, ServeConfig(
        max_len=args.prompt_len + args.decode_steps + 8,
        decode_steps=args.decode_steps, flight_size=args.flight,
        mean_jitter_s=args.jitter_ms / 1e3))

    for i in range(args.requests):
        batch = demo_requests(cfg, args.batch, args.prompt_len, seed=i)
        res = (eng.generate_flight(batch) if args.flight > 1
               else eng.generate(batch))
        print(f"req {i}: {res.latency_s*1e3:.0f} ms  "
              f"tokens={res.tokens[:, :6].tolist()}...")
    return 0


if __name__ == "__main__":
    sys.exit(main())
