"""Deterministic synthetic LM data pipeline, shardable by host.

Production shape: each host materialises only its shard of the global batch
(``host_batch_slice``), so the pipeline scales to any number of data hosts
with no coordination beyond the step index — the Raptor redundant-DP layer
(training.raptor_dp) reuses the same indexing to hand the SAME microbatch to
multiple flight members deterministically.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 1234
    # markov-ish synthetic text: token t+1 = f(token t) + noise, so models
    # actually have signal to learn (loss decreases in examples/)
    structure: float = 0.7


def _batch_tokens(cfg: ModelConfig, batch: int, seq: int, step: int,
                  dc: DataConfig, host_slice: slice) -> np.ndarray:
    rng = np.random.default_rng((dc.seed, step))
    b = host_slice.stop - host_slice.start
    base = rng.integers(0, cfg.vocab_size, size=(b, seq + 1), dtype=np.int64)
    # inject learnable structure: with prob `structure`, next = (prev*7+3)%V
    follow = (base[:, :-1] * 7 + 3) % cfg.vocab_size
    mask = rng.random((b, seq)) < dc.structure
    nxt = np.where(mask, follow, base[:, 1:])
    return np.concatenate([base[:, :1], nxt], axis=1).astype(np.int32)


def make_batch(cfg: ModelConfig, shape: ShapeConfig, step: int,
               dc: Optional[DataConfig] = None,
               host_slice: Optional[slice] = None) -> Dict[str, np.ndarray]:
    """One global (or host-sliced) training batch for any architecture."""
    dc = dc or DataConfig()
    b, s = shape.global_batch, shape.seq_len
    host_slice = host_slice or slice(0, b)
    toks = _batch_tokens(cfg, b, s, step, dc, host_slice)
    batch: Dict[str, np.ndarray] = {
        "labels": toks[:, 1:],
    }
    if cfg.embedding_inputs:
        rng = np.random.default_rng((dc.seed, step, 7))
        bsz = host_slice.stop - host_slice.start
        batch["embeddings"] = rng.standard_normal(
            (bsz, s, cfg.d_model)).astype(np.float32) * 0.02
    else:
        batch["tokens"] = toks[:, :-1]
    if cfg.is_encoder_decoder:
        rng = np.random.default_rng((dc.seed, step, 11))
        bsz = host_slice.stop - host_slice.start
        batch["enc_emb"] = rng.standard_normal(
            (bsz, s // 4, cfg.d_model)).astype(np.float32) * 0.02
    if cfg.mrope:
        bsz = host_slice.stop - host_slice.start
        pos = np.broadcast_to(np.arange(s, dtype=np.int32)[None], (bsz, s))
        batch["positions"] = np.broadcast_to(pos[None], (3, bsz, s)).copy()
    return batch


def data_iterator(cfg: ModelConfig, shape: ShapeConfig,
                  dc: Optional[DataConfig] = None,
                  start_step: int = 0,
                  host_slice: Optional[slice] = None) -> Iterator[Dict]:
    """Resumable: restart from any step index after checkpoint restore."""
    step = start_step
    while True:
        yield make_batch(cfg, shape, step, dc, host_slice)
        step += 1
