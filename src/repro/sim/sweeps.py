"""Device-sharded sweep subsystem: every config-grid sweep through ONE driver.

The paper's claims are sweep-shaped — delay ratio and failure rate vs load,
AZ count, flight size — and before this module each sweep family carried its
own copy of the pad-mask-trace plumbing (``sim/vector.py``'s bucket loop,
``sim/vector_queue.py``'s ``_pair_sweep``, the driver loops in
``sim/experiments.py``) and ran on ONE device.  A :class:`SweepPlan` is the
declarative form of a sweep: a config grid, a set of static-shape *buckets*
(grouped via the shared ``pow2_pad``/``bucket_by_pad`` helpers so ragged
axes like flight size share compilations), and one per-config core per
bucket.  The driver pads each bucket's config axis up to the device mesh,
runs it through ``shard_map`` over the 1-D ``("config",)`` mesh
(``launch.mesh.make_config_mesh``) — pure batching, so the sharded run is
bit-identical to the single-device one (tests/test_sweeps.py) — donates the
stacked per-config input buffers on accelerator backends, and shares the
jitted-runner cache across plans (plus the persistent XLA compile cache,
``benchmarks.run.enable_compile_cache``, for the cross-process case).

Multi-controller on CPU hosts: :func:`force_host_devices` forces
``--xla_force_host_platform_device_count`` before the jax backend
initializes, splitting the host into N devices so the sharded path runs —
and is CI-tested — on a plain GitHub runner.  The closed-loop grids shard
near-linearly (BENCH_sim.json ``sweep_sharded``): their event scans are
tiny-op dispatch-bound work XLA cannot intra-op-parallelize, exactly the
coordinator fan-out Wukong/Archipelago get their wins from.  The open-loop
cores are wide elementwise batches that already saturate a host's cores on
one device, so sharding them buys equivalence coverage, not throughput.
"""
from __future__ import annotations

import dataclasses
import functools
import os
from typing import Callable, Dict, List, Tuple

import jax
import jax.numpy as jnp

from repro.launch.mesh import make_config_mesh
from repro.sim.cluster import OverheadModel, lognormal_params
from repro.sim.vector import (VectorResult, VectorWorkload, _raptor_sweep_core,
                              _stock_sweep_core, bucket_by_pad)


# --------------------------------------------------------------------------
# CPU fallback: force a host-device mesh before the backend initializes
# --------------------------------------------------------------------------

def _backend_live() -> bool:
    try:
        from jax._src import xla_bridge
        return bool(xla_bridge._backends)
    except Exception:   # registry moved (newer jax): assume live -> no-op
        return True


def force_host_devices(n: int) -> int:
    """Ensure the process sees >= ``n`` devices by forcing XLA's host-
    platform device count — the CPU fallback for the multi-controller sweep
    path, so sharded sweeps run (and are CI-tested) on a GitHub runner.

    Must run before the jax backend initializes (i.e. before the first
    ``jax.devices()`` / jit dispatch).  The flag is APPENDED to any
    user-supplied ``XLA_FLAGS`` (never overwrites it), and a user-set
    device-count flag is respected as-is.  If the backend is already live
    and sees fewer than ``n`` devices, the request cannot take effect —
    that raises a clear ``RuntimeError`` instead of silently running the
    sweep unsharded.  Returns the live device count, so callers size
    their shard axis on the actual value, never the requested one.
    """
    flag = "--xla_force_host_platform_device_count"
    user_set = flag in os.environ.get("XLA_FLAGS", "")
    if not user_set and not _backend_live():
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "") + f" {flag}={int(n)}").strip()
    elif not user_set and jax.device_count() < int(n):
        raise RuntimeError(
            f"force_host_devices({n}) called after the jax backend "
            f"initialized with {jax.device_count()} device(s); call it "
            f"before the first jax.devices()/jit dispatch, or set "
            f"XLA_FLAGS={flag}={int(n)} in the environment")
    return jax.device_count()


def _resolve_devices(devices) -> tuple:
    """None -> every device; int -> first n devices; else as given."""
    if devices is None:
        return tuple(jax.devices())
    if isinstance(devices, int):
        return tuple(jax.devices()[:max(int(devices), 1)])
    return tuple(devices)


# --------------------------------------------------------------------------
# the driver
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SweepTask:
    """One static-shape bucket of a plan.

    ``core(key, cfg, shared)`` computes a single config: ``cfg`` is a tuple
    of that config's knobs, ``shared`` the broadcast arguments.  The driver
    vmaps it over the stacked config axis and shards that axis over the
    device mesh; ``key`` and ``shared`` are replicated to every shard.
    """
    tag: str                      # output slot ("raptor" / "stock")
    idxs: Tuple[int, ...]         # plan-level config indices in this bucket
    core: Callable
    key: object                   # PRNG key array, replicated
    cfg: tuple                    # per-config arrays, leading axis len(idxs)
    shared: tuple                 # broadcast scalars/arrays


@functools.lru_cache(maxsize=None)
def _sharded_runner(core, devices):
    """Jitted (config-vmapped, device-sharded) form of a bucket core.

    Cached per (core, device set); the core builders below are themselves
    lru-cached on their static shapes, so re-running a plan — or another
    plan sharing a bucket shape — reuses the compiled executable.
    """
    fn = jax.vmap(core, in_axes=(None, 0, None))
    if len(devices) > 1:
        from jax.experimental.shard_map import shard_map
        P = jax.sharding.PartitionSpec
        fn = shard_map(fn, mesh=make_config_mesh(devices),
                       in_specs=(P(), P("config"), P()),
                       out_specs=P("config"))
    # donating the stacked config buffers is free on accelerators — run()
    # passes per-dispatch copies, never the plan's own arrays, exactly so
    # they are safe to donate; the CPU runtime ignores donation with a
    # warning, so gate it there
    donate = (1,) if jax.default_backend() != "cpu" else ()
    return jax.jit(fn, donate_argnums=donate)


class SweepPlan:
    """A config grid plus the bucketed, device-shardable runners for it.

    ``run(devices=...)`` executes every bucket (config axis padded up to a
    multiple of the shard count with replicas of the bucket's first config,
    sliced back off afterwards) and hands each config's per-tag outputs to
    ``finalize(config, parts) -> dict``.  Because the shard axis is pure
    batching, results are bit-identical for any device count — a sharded
    sweep IS the single-device sweep, just faster.
    """

    def __init__(self, name: str, configs, tasks, finalize):
        self.name = name
        self.configs = list(configs)
        self.tasks = list(tasks)
        self.finalize = finalize
        self.validate()

    def validate(self) -> None:
        """Bucketing must partition the grid per output tag: every config
        index in exactly one bucket — a plan can never silently drop (or
        double-run) grid points."""
        for tag in {t.tag for t in self.tasks}:
            seen = sorted(i for t in self.tasks if t.tag == tag
                          for i in t.idxs)
            if seen != list(range(len(self.configs))):
                raise ValueError(
                    f"plan {self.name!r}: tag {tag!r} buckets cover "
                    f"{len(set(seen))}/{len(self.configs)} grid points")

    def run(self, devices=None) -> List[dict]:
        devs = _resolve_devices(devices)
        parts: List[Dict[str, object]] = [{} for _ in self.configs]
        for task in self.tasks:
            n = len(task.idxs)
            # Never shard down to a local batch of ONE config (except
            # n == 1, where every mesh size degenerates to the same
            # single-config program): a size-1 config axis lets XLA
            # collapse the vmap dimension and re-fuse the local program,
            # which moves transcendentals by an ulp and breaks the
            # bit-identical guarantee.  A local batch >= 2 keeps the
            # traced rank — and with it the per-element codegen — stable
            # across mesh sizes (tests/test_sweeps.py pins this).
            d = 1 if n == 1 else max(1, min(len(devs), n // 2))
            npad = -(-n // d) * d
            # on donating backends the dispatch consumes its input buffers,
            # so hand it COPIES — jnp.asarray would alias the plan's own
            # task.cfg arrays and a second run() would hit deleted buffers
            make = (jnp.array if jax.default_backend() != "cpu"
                    else jnp.asarray)
            cfg = tuple(make(a) for a in task.cfg)
            if npad > n:
                # pad the grid axis with replicas of the bucket's first
                # config; the surplus rows are sliced back off below
                cfg = jax.tree_util.tree_map(
                    lambda a: jnp.concatenate(
                        [a, jnp.broadcast_to(a[:1],
                                             (npad - n,) + a.shape[1:])]),
                    cfg)
            out = _sharded_runner(task.core, devs[:d])(
                task.key, cfg, task.shared)
            # ONE host transfer per output leaf: slicing per-config on
            # device and pulling 0-d results would serialize hundreds of
            # tiny blocking syncs into the timed path
            out = jax.device_get(out)
            for j, i in enumerate(task.idxs):
                parts[i][task.tag] = jax.tree_util.tree_map(
                    lambda o: o[j], out)
        return [self.finalize(c, p) for c, p in zip(self.configs, parts)]


# --------------------------------------------------------------------------
# open-loop pairs (the sim/vector.py family): pad-and-mask over flight size
# --------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _open_raptor_core(trials, f_pad, num_tasks, a_pad, dist, fail_prob,
                      faults, policy):
    def core(key, cfg, shared):
        flight, num_azs, rho, oh_mu, oh_sigma = cfg
        mean, offset, cv, stage_oh, slat = shared
        return _raptor_sweep_core(
            key, flight, num_azs, rho, mean, offset, cv, stage_oh, slat,
            oh_mu, oh_sigma, trials=trials, flight_max=f_pad,
            num_tasks=num_tasks, azs_max=a_pad, dist=dist,
            fail_prob=fail_prob, faults=faults, policy=policy)
    return core


@functools.lru_cache(maxsize=None)
def _open_stock_core(trials, num_tasks, dist, fail_prob, faults, policy):
    def core(key, cfg, shared):
        rho, oh_mu, oh_sigma = cfg
        mean, offset, cv = shared
        return _stock_sweep_core(
            key, rho, mean, offset, cv, oh_mu, oh_sigma, trials=trials,
            num_tasks=num_tasks, dist=dist, fail_prob=fail_prob,
            faults=faults, policy=policy)
    return core


def open_loop_pair_plan(wl: VectorWorkload, configs, *, trials: int = 20_000,
                        seed: int = 0) -> SweepPlan:
    """``sweep_pairs`` as a plan: many (flight, num_azs, rho, load) points,
    stock + raptor, raptor bucketed by pow2-padded flight size so every
    bucket shares one compilation with masked-member waste under 2x."""
    cfgs = [dict(flight=int(c["flight"]), num_azs=int(c["num_azs"]),
                 rho=float(c.get("rho", 0.95)),
                 load=c.get("load", "medium")) for c in configs]
    # Table-6 overhead regimes are keyed by (ha, load) — a 1-AZ config in
    # the same sweep as HA configs must NOT inherit the HA overhead row
    oh = {(c["num_azs"] > 1, c["load"]): lognormal_params(
        *OverheadModel.TABLE[(c["num_azs"] > 1, c["load"])]) for c in cfgs}

    def oh_of(c):
        return oh[(c["num_azs"] > 1, c["load"])]

    tasks = []
    for f_pad, idxs in sorted(
            bucket_by_pad(c["flight"] for c in cfgs).items()):
        sub = [cfgs[i] for i in idxs]
        a_pad = max(c["num_azs"] for c in sub)
        tasks.append(SweepTask(
            "raptor", tuple(idxs),
            _open_raptor_core(int(trials), f_pad, wl.num_tasks, a_pad,
                              wl.dist, wl.fail_prob, wl.faults,
                              wl.recovery),
            jax.random.PRNGKey(seed * 2 + 1),
            (jnp.array([c["flight"] for c in sub]),
             jnp.array([c["num_azs"] for c in sub]),
             jnp.array([c["rho"] for c in sub]),
             jnp.array([oh_of(c)[0] for c in sub]),
             jnp.array([oh_of(c)[1] for c in sub])),
            (wl.mean_ms, wl.offset_ms, wl.cv, wl.stage_overhead_ms, 0.5)))
    tasks.append(SweepTask(
        "stock", tuple(range(len(cfgs))),
        _open_stock_core(int(trials), wl.num_tasks, wl.dist, wl.fail_prob,
                         wl.faults, wl.recovery),
        jax.random.PRNGKey(seed * 2),
        (jnp.array([c["rho"] for c in cfgs]),
         jnp.array([oh_of(c)[0] for c in cfgs]),
         jnp.array([oh_of(c)[1] for c in cfgs])),
        (wl.mean_ms, wl.offset_ms, wl.cv)))

    def finalize(cfg, parts):
        r = VectorResult(*parts["raptor"], True)
        s = VectorResult(*parts["stock"], False)
        res = dict(cfg)
        res["raptor"] = r.summary()
        res["stock"] = s.summary()
        res["mean_ratio"] = res["raptor"]["mean"] / res["stock"]["mean"]
        return res

    return SweepPlan("open-loop-pairs", cfgs, tasks, finalize)


# --------------------------------------------------------------------------
# closed-loop pairs (the sim/vector_queue.py family): traced rate/overhead
# --------------------------------------------------------------------------

# The closed-loop cores fuse the success-conditioned summary reduction
# (core.analytics.summarize_masked_batch) into the sharded program: every
# config's percentile sort runs on its own device and only eight scalars
# come home, so the grid's wall time actually scales with the mesh instead
# of serializing on per-config host round-trips.

@functools.lru_cache(maxsize=None)
def _queue_raptor_core(jobs, W, A, F, graph, dist, fail_prob,
                       faults, policy, block, resolver, scan,
                       summary_backend):
    from repro.core.analytics import summarize_masked_batch
    from repro.sim.vector_queue import _raptor_trial_fn
    trial = _raptor_trial_fn(jobs, W, A, F, graph, dist, fail_prob,
                             faults, policy, block, resolver, scan,
                             summary_backend)

    def core(keys, cfg, shared):
        rate, oh_mu, oh_sigma = cfg
        rho, means, offset, cv, stage_oh, slat = shared
        resp, ok = jax.vmap(trial, in_axes=(0,) + (None,) * 9)(
            keys, rate, rho, means, offset, cv, stage_oh, slat,
            oh_mu, oh_sigma)
        return summarize_masked_batch(resp, ok)
    return core


@functools.lru_cache(maxsize=None)
def _queue_stock_core(jobs, W, A, graph, dist, fail_prob, faults,
                      policy, passes, has_extras, block, backend,
                      resolver, scan, summary_backend):
    from repro.core.analytics import summarize_masked_batch
    from repro.sim.vector_queue import _stock_trial_fn
    trial = _stock_trial_fn(jobs, W, A, graph, dist, fail_prob,
                            faults, policy, passes, has_extras, block,
                            backend, resolver, scan, summary_backend)

    def core(keys, cfg, shared):
        rate, oh_mu, oh_sigma = cfg
        rho, means, extras, offset, cv, stage_oh = shared
        resp, ok = jax.vmap(trial, in_axes=(0,) + (None,) * 9)(
            keys, rate, rho, means, extras, offset, cv, stage_oh,
            oh_mu, oh_sigma)
        return summarize_masked_batch(resp, ok)
    return core


def queue_pair_plan(sims, jobs: int, trials: int) -> SweepPlan:
    """A list of same-deployment ``QueueFlightSim``s as ONE closed-loop
    plan: arrival rate and the Table-6 overhead lognormal are the sharded
    config axes, stock and raptor each a single static-shape bucket.  This
    is the driver the fig6/fig7 load and utilisation grids run through —
    the dispatch-bound event scans are where device sharding pays
    near-linearly (see the module docstring).

    The substrate block configuration (``QueueFlightSim.engine_config``)
    is part of each bucket's static shape key alongside the padded event
    counts — sims sharing a plan must agree on it, or they could not share
    the bucket's compiled core."""
    s0 = sims[0]
    r_blk, r_res, r_scan = s0.engine_config("raptor")
    s_blk, s_res, s_scan = s0.engine_config("stock")
    for s in sims[1:]:
        if (s.engine_config("raptor") != (r_blk, r_res, r_scan)
                or s.engine_config("stock") != (s_blk, s_res, s_scan)
                or s.booking_backend != s0.booking_backend
                or s.summary_backend != s0.summary_backend):
            raise ValueError("sims in one queue plan must share the "
                             "substrate (block, resolver, scan, backend) "
                             "config — it is part of the bucket key")
        if s._fp != s0._fp or s._policy != s0._policy:
            # the fault environment and recovery policy are statics of
            # the compiled cores, so they join the bucket key too
            raise ValueError("sims in one queue plan must share the "
                             "fault profile and recovery policy — they "
                             "are statics of the bucket's compiled core")
    rates = jnp.array([s.rate_hz for s in sims])
    mus = jnp.array([s.oh_mu for s in sims])
    sigmas = jnp.array([s.oh_sigma for s in sims])
    wl = s0.wl
    all_idx = tuple(range(len(sims)))
    tasks = [
        SweepTask(
            "raptor", all_idx,
            _queue_raptor_core(
                int(jobs), s0.W, s0.A, s0.flight, wl.graph,
                wl.dist, wl.fail_prob, s0._fp, s0._policy,
                r_blk, r_res, r_scan, s0.summary_backend),
            s0._keys(trials, True),
            (rates, mus, sigmas),
            (s0.rho, jnp.asarray(wl.task_means, dtype=jnp.float32),
             wl.offset_ms, wl.cv, wl.raptor_stage_ms, s0.slat)),
        SweepTask(
            "stock", all_idx,
            _queue_stock_core(
                int(jobs), s0.W, s0.A, s0._sgraph,
                wl.dist, wl.fail_prob, s0._fp, s0._policy, s0._spasses,
                bool(s0._sextras.any()), s_blk, s0.booking_backend,
                s_res, s_scan, s0.summary_backend),
            s0._keys(trials, False),
            (rates, mus, sigmas),
            (s0.rho, jnp.asarray(s0._smeans), jnp.asarray(s0._sextras),
             wl.offset_ms, wl.cv, wl.stock_stage_ms)),
    ]

    def finalize(cfg, parts):
        def host(summ):
            return {k: (int(v) if k in ("n", "n_failed") else float(v))
                    for k, v in summ.items()}
        res = {"stock": host(parts["stock"]),
               "raptor": host(parts["raptor"])}
        res["mean_ratio"] = res["raptor"]["mean"] / res["stock"]["mean"]
        return res

    configs = [dict(rate_hz=s.rate_hz, load=s.load) for s in sims]
    return SweepPlan("queue-pairs", configs, tasks, finalize)
