"""Correlated fault injection: AZ brownouts + worker crashes as interval
tables.

The paper's central claim — Raptor's delay/failure gains are predictable
from *mutually independent* executions — only holds while the
infrastructure cooperates.  This module injects the two fault processes
that break it:

* **AZ brownouts**: each AZ alternates healthy/degraded through an on/off
  CTMC (exp(``az_mtbf_ms``) up, exp(``az_mttr_ms``) down).  While degraded,
  service times inflate by ``degraded_inflation`` and the per-attempt error
  probability rises to ``degraded_fail_prob``.  ``correlated=True`` drives
  every AZ from ONE shared process — the regime that destroys the
  independence assumption outright (experiments.fault_sweep measures the
  breakdown; EXPERIMENTS.md §faults).
* **worker crashes**: each worker fails after exp(``crash_mtbf_ms``) of
  wall-clock and is unavailable for ``crash_restart_ms``.  A crash kills
  the in-flight attempt at the crash instant (the attempt fails and is
  eligible for requeue under the active ``RecoveryPolicy``); bookings
  never start inside an outage — they are pushed past its end.

Both processes are **pre-drawn as interval tables** (``(n, max_intervals)``
start/end pairs) so the vectorized engines stay scan-friendly: the blocked
event-replay substrate needs every booking to be a deterministic function
of the observed worker free-at vector plus exogenous inputs, and a static
table is exactly such an input — which is why every blocked/logdepth
config stays bitwise-identical to the block=1 sequential oracle *with
faults enabled* (tests/test_queue_properties.py).

Truncation convention (shared by the scalar oracle and the vector
engines so agreement tests compare like with like): after the
``max_intervals``-th drawn cycle the process is healthy forever.  Size
the table to the horizon via :meth:`FaultProfile.coverage_ms`.

Pure interval helpers come in two flavors kept in lockstep: batched
``jnp`` forms used inside jitted scan bodies, and scalar ``*_np`` forms
for the event-driven oracle.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np


@dataclasses.dataclass(frozen=True)
class FaultProfile:
    """Declarative fault environment (hashable — it joins the static keys
    of the cached trial builders and the sweep bucket keys).

    Defaults describe a healthy cluster; ``enabled`` is False until a
    brownout or crash process is configured.
    """
    az_mtbf_ms: float = 0.0        # mean healthy dwell per AZ (0 = off)
    az_mttr_ms: float = 0.0        # mean degraded dwell per AZ
    correlated: bool = False       # one shared brownout process for all AZs
    degraded_inflation: float = 1.0   # service multiplier while degraded
    degraded_fail_prob: float = 0.0   # per-attempt error prob while degraded
    crash_mtbf_ms: float = 0.0     # mean per-worker uptime (0 = off)
    crash_restart_ms: float = 0.0  # outage length after a crash
    max_intervals: int = 64        # static brownout table width per AZ
    max_crashes: int = 32          # static crash table width per worker

    @property
    def has_brownouts(self) -> bool:
        return self.az_mtbf_ms > 0.0 and self.az_mttr_ms > 0.0

    @property
    def has_crashes(self) -> bool:
        return self.crash_mtbf_ms > 0.0

    @property
    def enabled(self) -> bool:
        return self.has_brownouts or self.has_crashes

    @property
    def stationary_degraded(self) -> float:
        """CTMC stationary probability of the degraded state."""
        if not self.has_brownouts:
            return 0.0
        return self.az_mttr_ms / (self.az_mtbf_ms + self.az_mttr_ms)

    def coverage_ms(self) -> float:
        """Expected horizon the drawn tables cover (mean cycle x width).
        Size ``max_intervals``/``max_crashes`` so this comfortably exceeds
        the replay horizon — beyond the table the process is healthy."""
        covs = []
        if self.has_brownouts:
            covs.append((self.az_mtbf_ms + self.az_mttr_ms)
                        * self.max_intervals)
        if self.has_crashes:
            covs.append((self.crash_mtbf_ms + self.crash_restart_ms)
                        * self.max_crashes)
        return min(covs) if covs else math.inf

    # -- table draws (numpy: the scalar oracle's stream) -----------------
    def brownout_tables_np(self, rng: np.random.Generator, num_azs: int):
        """(num_azs, I) start/end tables; disabled -> [inf, inf) sentinel."""
        if not self.has_brownouts:
            s = np.full((num_azs, 1), np.inf)
            return s, s.copy()
        n = 1 if self.correlated else num_azs
        up = rng.exponential(self.az_mtbf_ms, (n, self.max_intervals))
        down = rng.exponential(self.az_mttr_ms, (n, self.max_intervals))
        ends = np.cumsum(up + down, axis=1)
        starts = ends - down
        if self.correlated:
            starts = np.broadcast_to(starts, (num_azs, self.max_intervals))
            ends = np.broadcast_to(ends, (num_azs, self.max_intervals))
        return np.ascontiguousarray(starts), np.ascontiguousarray(ends)

    def crash_tables_np(self, rng: np.random.Generator, num_workers: int):
        if not self.has_crashes:
            s = np.full((num_workers, 1), np.inf)
            return s, s.copy()
        gaps = rng.exponential(self.crash_mtbf_ms,
                               (num_workers, self.max_crashes))
        ends = np.cumsum(gaps + self.crash_restart_ms, axis=1)
        return ends - self.crash_restart_ms, ends

    # -- table draws (jnp: inside a jitted trial, from a key split) ------
    def brownout_tables(self, key, num_azs: int):
        import jax
        import jax.numpy as jnp
        if not self.has_brownouts:
            s = jnp.full((num_azs, 1), jnp.inf)
            return s, s
        n = 1 if self.correlated else num_azs
        ku, kd = jax.random.split(key)
        up = jax.random.exponential(
            ku, (n, self.max_intervals)) * self.az_mtbf_ms
        down = jax.random.exponential(
            kd, (n, self.max_intervals)) * self.az_mttr_ms
        ends = jnp.cumsum(up + down, axis=1)
        starts = ends - down
        if self.correlated:
            starts = jnp.broadcast_to(starts,
                                      (num_azs, self.max_intervals))
            ends = jnp.broadcast_to(ends, (num_azs, self.max_intervals))
        return starts, ends

    def crash_tables(self, key, num_workers: int):
        import jax
        import jax.numpy as jnp
        if not self.has_crashes:
            s = jnp.full((num_workers, 1), jnp.inf)
            return s, s
        gaps = jax.random.exponential(
            key, (num_workers, self.max_crashes)) * self.crash_mtbf_ms
        ends = jnp.cumsum(gaps + self.crash_restart_ms, axis=1)
        return ends - self.crash_restart_ms, ends


#: healthy cluster — the engines' static no-op (compiles to the pre-fault
#: code paths bit-for-bit)
NO_FAULTS = FaultProfile()


# --------------------------------------------------------------------------
# interval helpers — batched jnp forms (vector scan bodies)
# --------------------------------------------------------------------------
# ``starts``/``ends`` are sorted disjoint interval tables with one trailing
# axis; the query time broadcasts against every leading axis.  All three
# are pure elementwise/reduction arithmetic, so they preserve the blocked
# substrate's determinism-in-(wf, exogenous-tables) contract.

def interval_active(t, starts, ends):
    """True where ``t`` falls inside an interval ([start, end))."""
    import jax.numpy as jnp
    return jnp.any((t[..., None] >= starts) & (t[..., None] < ends),
                   axis=-1)


def push_out(t, starts, ends):
    """Earliest time >= ``t`` outside every interval.  One pass suffices:
    the intervals are disjoint, and an interval's end never lands inside a
    later interval (gaps are a.s. positive)."""
    import jax.numpy as jnp
    hit = (t[..., None] >= starts) & (t[..., None] < ends)
    bump = jnp.max(jnp.where(hit, ends, -jnp.inf), axis=-1)
    return jnp.maximum(t, bump)


def first_start_in(s, e, starts):
    """Earliest interval start strictly inside (s, e); inf when none.
    (The crash-kill query: an attempt running over a crash start dies
    there.  ``s`` itself is never inside an outage — bookings are pushed
    out first — so strict comparison is exact.)"""
    import jax.numpy as jnp
    cand = jnp.where((starts > s[..., None]) & (starts < e[..., None]),
                     starts, jnp.inf)
    return jnp.min(cand, axis=-1)


# --------------------------------------------------------------------------
# interval helpers — scalar numpy forms (the event-driven oracle)
# --------------------------------------------------------------------------

def interval_active_np(t: float, starts, ends) -> bool:
    return bool(np.any((t >= starts) & (t < ends)))


def push_out_np(t: float, starts, ends) -> float:
    hit = (t >= starts) & (t < ends)
    if hit.any():
        return float(ends[hit].max())
    return float(t)


def first_start_in_np(s: float, e: float, starts) -> float:
    inside = starts[(starts > s) & (starts < e)]
    return float(inside.min()) if inside.size else math.inf
