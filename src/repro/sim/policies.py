"""Attempt-level recovery policy: timeout, bounded retry, hedging.

Raptor's F x K racing is one point in the recovery design space — a hedge
issued at latency threshold 0 with a budget of F copies.  ``RecoveryPolicy``
names the rest of the space declaratively so BOTH engines (and the live
``core.scheduler`` flight) consume the same knobs:

* ``timeout_ms`` — an attempt running longer than this fails at the
  timeout (the cap applies to the attempt's busy time, service plus the
  per-attempt stage hop);
* ``max_retries``/``backoff_ms``/``backoff_jitter`` — a failed attempt is
  retried on the SAME worker after ``backoff_ms * 2**r * (1 + jitter*U)``;
  the whole chain counts as one racing attempt (a member exhausts a task
  only after the full budget — the ``dead_after`` accounting in
  ``sim/flights.py`` and ``core/scheduler.py`` respects this);
* ``hedge_ms`` — stock engine only: if the primary attempt is still
  running ``hedge_ms`` after it started, a duplicate is enqueued on
  another worker (no cancellation: both run to completion, first success
  wins — racing IS this knob at 0 with budget F, so the raptor engines
  ignore it).

Semantics shared by the scalar oracle and the vector engines (agreement
tests compare like with like):

* **deterministic re-execution**: the service time is a property of the
  invocation, so retried/hedged attempts reuse the SAME service draw.
  Retries still help because the *environment* changes between attempts —
  the brownout state at the new start time, crash avoidance, queue timing;
* per-attempt error uniforms are redrawn (transient errors);
* intermediate chain failures broadcast nothing (paper §3.3.4 — only the
  chain's final outcome is visible to peers).

The chain fold below turns a whole timeout/retry/backoff chain into ONE
(end, failed) pair computed at scheduling time.  That keeps the vector
race's one-event-per-(member, task) structure — the tight event budgets
survive policy injection — and the scalar oracle folds the identical
arithmetic, so the two stay distributionally in lockstep.  The two
implementations (batched jnp / scalar np) must not drift apart.
"""
from __future__ import annotations

import dataclasses
import math

from repro.sim.faults import (FaultProfile, first_start_in, first_start_in_np,
                              interval_active, interval_active_np, push_out,
                              push_out_np)


@dataclasses.dataclass(frozen=True)
class RecoveryPolicy:
    timeout_ms: float = math.inf
    max_retries: int = 0
    backoff_ms: float = 0.0
    backoff_jitter: float = 0.0    # multiplicative U[1, 1+jitter) on backoff
    hedge_ms: float = math.inf     # stock only; raptor racing = hedge-at-0

    @property
    def is_default(self) -> bool:
        return (math.isinf(self.timeout_ms) and self.max_retries == 0
                and math.isinf(self.hedge_ms))

    @property
    def has_hedge(self) -> bool:
        return math.isfinite(self.hedge_ms)

    @property
    def chain_attempts(self) -> int:
        """Attempts in one retry chain (primary + retries)."""
        return 1 + self.max_retries

    @property
    def stock_attempts(self) -> int:
        """Attempt slots per stock task: the chain plus the hedge copy."""
        return self.chain_attempts + (1 if self.has_hedge else 0)

    def backoff(self, r: int, u: float) -> float:
        """Backoff before retry ``r+1`` (exponential, jittered)."""
        return self.backoff_ms * (2.0 ** r) * (1.0 + self.backoff_jitter * u)


#: the no-op policy — engines compile to their pre-policy paths
NO_RECOVERY = RecoveryPolicy()


def can_fail(base_fail: float, faults: FaultProfile | None,
             policy: RecoveryPolicy | None) -> bool:
    """Static: can ANY attempt outcome be a failure?  Gates the race event
    budgets, the closed forms, and the error-uniform draws."""
    if base_fail > 0.0:
        return True
    if policy is not None and math.isfinite(policy.timeout_ms):
        return True
    if faults is not None and faults.enabled:
        if faults.degraded_fail_prob > 0.0 or faults.has_crashes:
            return True
    return False


# --------------------------------------------------------------------------
# attempt arithmetic — one attempt, then the folded chain
# --------------------------------------------------------------------------
# An attempt asked to start at t on worker w in AZ a:
#   s       = push_out(t, crash outages of w)        (never start in one)
#   deg     = AZ a degraded at s
#   zi      = z * (inflation if deg else 1)
#   dur     = min(zi, timeout);  timeout-fail iff zi > timeout
#   p       = degraded_fail_prob if deg else base_fail;  error iff U < p
#   crash   = first crash start in (s, s+dur) kills the attempt there
#   end     = crash time if crashed else s + dur
# The chain runs attempts until one succeeds or the budget is spent; the
# next attempt starts at end + backoff(r).

def fold_chain(t0, z, u_err, u_jit, bs, be, cs, ce, *,
               policy: RecoveryPolicy, faults: FaultProfile | None,
               base_fail: float):
    """Batched jnp chain fold.

    ``t0``/``z``: (...,) requested start and base attempt duration;
    ``u_err``: (..., R+1) per-attempt error uniforms; ``u_jit``: (..., R)
    backoff jitter uniforms; ``bs``/``be``: (..., I) brownout tables of
    each lane's AZ; ``cs``/``ce``: (..., C) crash tables of its worker.
    Returns (end, failed) — the chain's completion time and final outcome.
    Statically unrolled over the retry budget (R is tiny).
    """
    import jax.numpy as jnp
    infl = faults.degraded_inflation if faults is not None else 1.0
    pdeg = (faults.degraded_fail_prob if faults is not None else base_fail)
    end = jnp.zeros_like(t0)
    failed = jnp.ones(t0.shape, dtype=bool)
    settled = jnp.zeros(t0.shape, dtype=bool)
    t = t0
    for r in range(policy.max_retries + 1):
        s = push_out(t, cs, ce)
        deg = interval_active(s, bs, be)
        zi = z * jnp.where(deg, infl, 1.0)
        dur = jnp.minimum(zi, policy.timeout_ms)
        p = jnp.where(deg, pdeg, base_fail)
        a_fail = (u_err[..., r] < p) | (zi > policy.timeout_ms)
        c1 = first_start_in(s, s + dur, cs)
        crashed = c1 < s + dur
        a_end = jnp.where(crashed, c1, s + dur)
        a_fail = a_fail | crashed
        end = jnp.where(settled, end, a_end)
        failed = jnp.where(settled, failed, a_fail)
        settled = settled | ~a_fail
        if r < policy.max_retries:
            t = a_end + policy.backoff_ms * (2.0 ** r) * (
                1.0 + policy.backoff_jitter * u_jit[..., r])
    return end, failed


def chain_transform(z, u_err, u_jit, deg, *, policy: RecoveryPolicy,
                    faults: FaultProfile | None, base_fail: float):
    """Open-loop chain fold — the zero-queueing limit of
    :func:`fold_chain`.

    The open-loop tier (:mod:`repro.sim.vector`) has no absolute clock:
    one trial is one invocation on an idle cluster, so the brownout state
    is a stationary snapshot frozen for the invocation (``deg``, drawn at
    ``FaultProfile.stationary_degraded``) and crash processes — which
    need wall-clock booking times — do not apply, nor does hedging
    (a hedge needs the booking time of the primary; closed-loop only).
    With the AZ state frozen and the service draw reused (deterministic
    re-execution), an attempt's duration and timeout outcome repeat
    exactly, so the chain reduces to a *draw transform*: total busy time
    = attempt durations + backoffs while failing, final outcome = every
    attempt errored (errors re-roll per attempt).

    ``z``: (...,) base durations; ``u_err``: (..., R+1); ``u_jit``:
    (..., R); ``deg``: (...,) bool.  Returns (duration, failed).
    """
    import jax.numpy as jnp
    infl = faults.degraded_inflation if faults is not None else 1.0
    pdeg = (faults.degraded_fail_prob if faults is not None else base_fail)
    zi = z * jnp.where(deg, infl, 1.0)
    dur1 = jnp.minimum(zi, policy.timeout_ms)
    tfail = zi > policy.timeout_ms
    p = jnp.where(deg, pdeg, base_fail)
    failed = (u_err[..., 0] < p) | tfail
    total = dur1
    for r in range(1, policy.max_retries + 1):
        a_fail = (u_err[..., r] < p) | tfail
        back = policy.backoff_ms * (2.0 ** (r - 1)) * (
            1.0 + policy.backoff_jitter * u_jit[..., r - 1])
        total = jnp.where(failed, total + back + dur1, total)
        failed = failed & a_fail
    return total, failed


def attempt_outcome_np(t: float, z: float, u_err: float, deg_bs, deg_be,
                       cs, ce, *, policy: RecoveryPolicy,
                       faults: FaultProfile | None, base_fail: float):
    """One scalar attempt: returns (start, end, failed)."""
    s = push_out_np(t, cs, ce)
    deg = (faults is not None and interval_active_np(s, deg_bs, deg_be))
    zi = z * (faults.degraded_inflation if deg else 1.0) \
        if faults is not None else z
    dur = min(zi, policy.timeout_ms)
    p = ((faults.degraded_fail_prob if deg else base_fail)
         if faults is not None else base_fail)
    a_fail = (u_err < p) or (zi > policy.timeout_ms)
    c1 = first_start_in_np(s, s + dur, cs)
    crashed = c1 < s + dur
    end = c1 if crashed else s + dur
    return s, end, (a_fail or crashed)


def fold_chain_np(t0: float, z: float, rng, deg_bs, deg_be, cs, ce, *,
                  policy: RecoveryPolicy, faults: FaultProfile | None,
                  base_fail: float):
    """Scalar chain fold — the oracle's twin of :func:`fold_chain`.
    Draws the per-attempt error/jitter uniforms from ``rng`` (the vector
    engines pre-draw theirs; both are i.i.d. per attempt)."""
    t = float(t0)
    end, a_fail = t, True
    for r in range(policy.max_retries + 1):
        _, end, a_fail = attempt_outcome_np(
            t, z, float(rng.random()), deg_bs, deg_be, cs, ce,
            policy=policy, faults=faults, base_fail=base_fail)
        if not a_fail:
            return end, False
        if r < policy.max_retries:
            t = end + policy.backoff(r, float(rng.random()))
    return end, True
