"""Closed-loop vectorized cluster engine: batched M/G/c worker queues and
DAG flights replayed on-device.

``sim/vector.py`` covers the open-loop zero-queueing limit — one invocation
on an idle cluster.  This module closes the loop: each trial replays a whole
Poisson arrival stream against a finite worker pool (the Table-6 overhead
regime's deployment), so the load-dependent paper figures (fig6's load ×
scale grid, fig7's DAG workloads, Table 8 at real utilisation) run as dense
tensors instead of crawling through the scalar event loop.

Structure (all on-device, ``vmap`` over trials and — for sweeps — configs):

* an outer ``lax.scan`` over arrival events carries the per-worker
  free-at-time vector; each arriving job claims workers (HA placement:
  member ``m`` waits for the earliest-free worker in AZ ``m % A``), races
  its flight, and scatters the member release times back into the pool;
* the flight race itself is a fixed-trip one-hot event scan like
  ``sim.vector._flight_trial``, extended with per-member dependency masks:
  a member whose next task in sequence has unmet dependencies parks
  (``fin = inf``) and is woken by the completion broadcast half an RTT
  later — wordcount and thumbnail manifests replay with the scalar
  ``FlightSim``'s §3.3.3/§3.3.4 semantics (cyclic-shift sequences from
  ``core.dag.execution_sequence``, head-of-line dependency waits,
  first-success broadcast preemption, at-most-one attempt per member);
* the stock path replays the fork-join at TASK granularity: every job's
  per-task ready-time streams (arrival + overhead for roots, dependency
  finish + storage hop + control-plane draw for staged tasks) are merged
  into ONE sorted event stream per trial, and the replay books a worker
  per *task* in ready order — the scalar oracle's task-level FCFS backlog.
  Staged ready times depend on queueing, so they are materialized by a
  bounded fixed point over stage depth (see ``_stock_trial_fn``);
  dep-free stock graphs are exact in one pass.

Both closed-loop replays run on the blocked event-replay substrate
(:mod:`repro.sim.scan_core`): the per-trial event stream is chunked into
blocks of ``block`` events, all bookings inside a block are resolved by a
bounded parallel fixed point over the worker free-at vector (raptor /
trace: the worker-identity Jacobi; stock measurement: the order-statistic
form), and only that W-vector crosses blocks — sequential depth drops
from O(jobs) to O(jobs/block · passes) while the intra-block work
vectorizes across the (trials × block) plane.  The DAG flight race rides
the same substrate: inside a block it runs once as a (block,)-wide batch
per fixed-point pass instead of once per job event.  ``block=1`` is
bit-for-bit the pre-blocking sequential scan and remains the oracle path
(tests/test_queue_properties.py pins block-size invariance); the default
resolves per engine and backend (``auto_config``): the fixed point is the
depth-reduction (accelerator) mode — its pass count tracks intra-block
queueing chains, which HA placement couples to whole cascades — and the
fused unrolled chunks are the host-throughput mode (EXPERIMENTS.md).

Arrival rate, rho, and the Table-6 overhead parameters are *traced*
arguments, so a whole load sweep shares one compilation via ``vmap`` over
the config axis (``sweep_runner``).

Fidelity notes (vs the scalar oracle, tests/test_sim_queue.py):

* staged stock ready times self-consistently converge through the bounded
  fixed point; with the default pass budget the wordcount stock path
  tracks the scalar task-FCFS oracle within 10% on mean AND p99 through
  util 0.75 (the regime where the old whole-job admission read ~4x
  pessimistic — ROADMAP's former known gap);
* the scalar sim draws ONE control-plane hop per stage-completion event
  (shared by every task it unblocks); the vector path draws one per
  unblocked task — same mean, negligibly lighter max over fan-outs;
* a dependency wait inside a flight ends exactly ``stream_latency_ms``
  after the unblocking broadcast (the scalar sim polls every half-RTT, so
  it lands within one poll of the same instant);
* with ``fail_prob > 0`` *and* dependencies, a fully-deadlocked flight
  (every member parked on a task whose attempts all errored) terminates
  with ``ok=False`` at its last event — the same convention the scalar
  sim now follows (``FlightSim._check_deadlock``), so every admitted job
  is accounted by BOTH engines and the scalar/vector agreement tests
  compare like with like (tests/test_sim_queue.py's deadlock test).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core.analytics import summarize_batch
from repro.core.workflow import WorkflowGraph, compile_spec, fanout, task
from repro.sim.cluster import OverheadModel, lognormal_params
from repro.sim.faults import (FaultProfile, first_start_in, interval_active,
                              push_out)
from repro.sim.policies import (NO_RECOVERY, RecoveryPolicy, can_fail,
                                fold_chain)
from repro.sim.scan_core import (blocked_bestfit_booking,
                                 blocked_event_replay, stock_booking_fins)
from repro.sim.vector import unit_draws
from repro.sim.workloads import (ETL_QUARANTINE_MS, KEYGEN_CV,
                                 KEYGEN_OFFSET_MS, THUMB_CV,
                                 THUMB_DOWNLOAD_MS, WC_STORAGE_HOP_MS,
                                 etl_graph, keygen_graph, mapreduce_graph,
                                 thumbnail_graph, thumbnail_stock_graph,
                                 wordcount_graph)
from repro.sim.workloads import arrival_rate_hz as _rate_for_load


@dataclasses.dataclass(frozen=True)
class QueueWorkload:
    """One compiled manifest bound to the vector engines' service model.

    ``graph`` is the workflow compiler's IR (:mod:`repro.core.workflow`):
    frozen and hashable, it IS the static key the cached trial builders
    and sweep bucket cores compile against — per-member sequences,
    dependency masks, and conditional select masks all derive from it.
    The stock graph may differ (thumbnail's stock functions re-download
    the source, so its task list drops the shared download stage and each
    task pays ``stock_extra_means`` as a second independent service
    draw); conditionals are always flattened for stock — the baseline has
    no data-dependent short-circuiting.
    """
    graph: WorkflowGraph
    flight: int
    dist: str = "exp"                       # "exp" | "lognorm" | "pareto"
    cv: float = 1.0
    offset_ms: float = 0.0
    raptor_stage_ms: float = 0.5            # stream hop per attempt
    stock: WorkflowGraph = None             # alternative stock-path graph
    stock_extra_means: Tuple[float, ...] = None
    stock_stage_ms: float = 0.0             # storage round-trip per stage hop
    fail_prob: float = 0.0
    work_est_ws: float = 2.0
    # fault environment + recovery policy carried with the workload (both
    # frozen/hashable, so they ride the static lru keys and the sweep
    # bucket keys); QueueFlightSim kwargs override
    faults: FaultProfile = None
    recovery: RecoveryPolicy = None

    @property
    def name(self) -> str:
        return self.graph.name

    @property
    def tasks(self) -> Tuple[str, ...]:
        return self.graph.tasks

    @property
    def task_means(self) -> Tuple[float, ...]:
        return self.graph.means

    def stock_graph(self) -> WorkflowGraph:
        g = self.stock if self.stock is not None else self.graph
        return g.flatten()

    def stock_extras(self) -> Tuple[float, ...]:
        if self.stock_extra_means is None:
            return (0.0,) * self.stock_graph().K
        return self.stock_extra_means


def keygen_queue(fail_prob: float = 0.0, faults: FaultProfile = None,
                 recovery: RecoveryPolicy = None) -> QueueWorkload:
    """ssh-keygen: two independent entropy-bound tasks, flight of 2."""
    return QueueWorkload(
        keygen_graph(), flight=2,
        dist="lognorm", cv=KEYGEN_CV, offset_ms=KEYGEN_OFFSET_MS,
        fail_prob=fail_prob, work_est_ws=1.9,
        faults=faults, recovery=recovery)


def wordcount_queue(fail_prob: float = 0.0, faults: FaultProfile = None,
                    recovery: RecoveryPolicy = None) -> QueueWorkload:
    """Map-reduce: split -> 4 maps -> reduce; stock pays the S3 hop."""
    return QueueWorkload(wordcount_graph(), flight=2,
                         dist="exp", stock_stage_ms=WC_STORAGE_HOP_MS,
                         fail_prob=fail_prob, work_est_ws=4.2,
                         faults=faults, recovery=recovery)


def thumbnail_queue(fail_prob: float = 0.0, faults: FaultProfile = None,
                    recovery: RecoveryPolicy = None) -> QueueWorkload:
    """Download + 4 resizes; stock functions each re-download the source."""
    return QueueWorkload(
        thumbnail_graph(), flight=4,
        dist="lognorm", cv=THUMB_CV,
        stock=thumbnail_stock_graph(),
        stock_extra_means=(THUMB_DOWNLOAD_MS,) * 4,
        fail_prob=fail_prob, work_est_ws=5.6,
        faults=faults, recovery=recovery)


def etl_queue(rank: int = 6, fail_prob: float = 0.08,
              faults: FaultProfile = None,
              recovery: RecoveryPolicy = None) -> QueueWorkload:
    """Workload-bank ETL pipeline (see :func:`repro.sim.workloads
    .etl_graph`): wide transform fan-out behind a ``validate`` guard
    whose outcome routes poison jobs to quarantine — the conditional
    mask-select path of the compiled IR.  ``fail_prob`` doubles as the
    poison rate."""
    g = etl_graph(rank)
    work = (sum(g.means) - ETL_QUARANTINE_MS) / 1000.0
    return QueueWorkload(g, flight=3, dist="exp",
                         stock_stage_ms=WC_STORAGE_HOP_MS,
                         fail_prob=fail_prob, work_est_ws=work,
                         faults=faults, recovery=recovery)


def mapreduce_queue(rank: int = 4, reducers: int = 2,
                    fail_prob: float = 0.0,
                    faults: FaultProfile = None,
                    recovery: RecoveryPolicy = None) -> QueueWorkload:
    """Workload-bank ranked map-reduce with a sync barrier (see
    :func:`repro.sim.workloads.mapreduce_graph`)."""
    g = mapreduce_graph(rank, reducers)
    return QueueWorkload(g, flight=3, dist="exp",
                         stock_stage_ms=WC_STORAGE_HOP_MS,
                         fail_prob=fail_prob,
                         work_est_ws=sum(g.means) / 1000.0,
                         faults=faults, recovery=recovery)


def heavytail_queue(num_tasks: int = 2, mean_ms: float = 1000.0,
                    flight: int = 2, cv: float = 2.5, dist: str = "pareto",
                    fail_prob: float = 0.0,
                    faults: FaultProfile = None,
                    recovery: RecoveryPolicy = None) -> QueueWorkload:
    """Heavy-tailed service family for the streaming traffic bank.

    ``dist`` picks the tail: "pareto" (power-law, alpha = 1 +
    sqrt(1 + 1/cv^2) > 2 so the mean load target still holds — see
    :func:`repro.sim.vector.unit_draws`) or "lognorm" at high cv.  Both
    keep unit mean, so ``work_est_ws`` and the UTIL load targets stay
    comparable with :func:`exponential_queue` at equal ``mean_ms``.
    """
    if dist not in ("pareto", "lognorm"):
        raise ValueError(
            f"heavy-tail dist must be 'pareto' or 'lognorm', got {dist!r}")
    if cv <= 0.0:
        raise ValueError(f"cv must be positive, got {cv}")
    return QueueWorkload(
        compile_spec(fanout(task("t", mean_ms), num_tasks),
                     name=f"{dist}{num_tasks}"),
        flight=flight, dist=dist, cv=cv, fail_prob=fail_prob,
        work_est_ws=num_tasks * mean_ms / 1000.0,
        faults=faults, recovery=recovery)


def exponential_queue(num_tasks: int = 2, mean_ms: float = 1000.0,
                      flight: int = 2, fail_prob: float = 0.0,
                      faults: FaultProfile = None,
                      recovery: RecoveryPolicy = None) -> QueueWorkload:
    """Pure exp(mu) independent tasks — the §4.2.1 theory's hypothesis."""
    return QueueWorkload(
        compile_spec(fanout(task("t", mean_ms), num_tasks),
                     name=f"exp{num_tasks}"),
        flight=flight, dist="exp", fail_prob=fail_prob,
        work_est_ws=num_tasks * mean_ms / 1000.0,
        faults=faults, recovery=recovery)


# --------------------------------------------------------------------------
# one flight race with dependency masks (the DAG-aware event scan)
# --------------------------------------------------------------------------

def dag_flight_trial(z_seq, fail_seq, t_join, seq, dep_mask, slat,
                     direct_start: bool = False, num_events: int = None,
                     no_failures: bool = False, recovery=None, cond=None):
    """Replay one flight of a (possibly DAG) manifest.

    Like ``sim.vector._flight_trial`` but members must respect ``dep_mask``
    ((K, K) bool, ``dep_mask[t, d]`` = task t needs task d): a member whose
    next task in sequence is not yet runnable parks (``fin = inf``) and is
    woken by the completion broadcast.  Member joins are modelled as events
    too (``cur = -1`` sentinel), so queue-delayed join times flow through
    the same scan.  Returns ``(t_resp, ok, t_release)`` with per-member
    worker release times (sequence exhausted, or flight end).

    ``direct_start=True`` (valid only when every member's first task is
    dependency-free and first tasks are member-distinct, so a late joiner
    can never find its first task already completed mid-flight) skips the
    F join events: members begin mid-attempt at ``t_join`` and the scan
    shrinks from F*(K+1) to F*K trips — the fast path for the fig6 sweep.

    ``num_events`` overrides the scan trip count with a tighter exact
    budget when the caller can prove one.  The load-bearing case: with
    ``fail_prob == 0`` every non-join event is the completion of a
    *distinct* task (a success broadcast preempts any peer mid-that-task,
    so no task completes twice, and a parked member's wake rides the
    completion event that unblocks it), so K completions + the F joins
    bound the replay — the closed-loop engines' races run at K instead of
    F*K trips, the hottest-loop win of the blocked rewrite
    (tests/test_queue_properties.py pins exactness against the full
    budget bitwise).

    ``no_failures=True`` (static) additionally drops the per-member
    attempted mask from the carry: an error-free attempt only ever ends
    because its task completed (by the member itself, or by the peer
    whose broadcast preempted it), so "attempted by me" implies "done"
    and the head-of-line candidate mask collapses to ``~done[seq]``.

    ``recovery`` (optional) is the fault/policy bundle ``(policy, faults,
    base_fail, bs, be, cs, ce, u_err, u_jit)``: per-member brownout
    tables of the PLACED AZ (``bs``/``be``, (F, I)), crash tables of the
    placed worker ((F, C)), and pre-drawn per-attempt uniforms
    ((F, K, R+1) errors / (F, K, R) backoff jitter).  Each launch then
    folds a whole timeout/retry/backoff chain into its ONE race event
    (``sim.policies.fold_chain``) — retries re-run on the same worker
    with the same service draw (deterministic re-execution), the member
    stays busy for the whole chain, and the first-success broadcast
    preempts a chain as a unit.  ``fail_seq`` is ignored in this mode
    (errors live in the fold's uniforms).

    ``cond`` (optional, static) is the compiled IR's conditional select
    pair ``(cond_guard, cond_sense)`` — per-task guard index (-1 =
    unconditional) and required guard outcome.  A guard task completes
    on its FIRST finished attempt whether or not that attempt erred
    (the error is the branch OUTCOME, not a job failure), and the same
    event mask-cancels every task gated on the opposite outcome: losers
    are marked done without consuming events, so the race budgets above
    still hold and the flight completes when the winning arm does.
    """
    F, K = z_seq.shape
    if recovery is not None:
        (r_pol, r_fp, r_base_fail, r_bs, r_be, r_cs, r_ce,
         u_err, u_jit) = recovery
    # dep_mask is a trace-time constant (the manifest), so a dep-free
    # workload statically elides the runnable computation below
    has_deps = bool(np.asarray(dep_mask).any())
    # likewise the conditional select masks: cond=None (or all -1)
    # compiles the exact pre-conditional jaxpr
    has_cond = cond is not None and any(g >= 0 for g in cond[0])
    if has_cond:
        c_gated = jnp.array([g >= 0 for g in cond[0]])
        c_guard = jnp.array([g if g >= 0 else 0 for g in cond[0]])
        c_sense = jnp.array(list(cond[1]))
        c_is_guard = jnp.array(
            [k in {g for g in cond[0] if g >= 0} for k in range(K)])
    k_ar = jnp.arange(K)
    done0 = jnp.zeros(K, dtype=bool)
    released0 = jnp.zeros((F,), dtype=bool)
    trel0 = jnp.zeros((F,))
    if direct_start:
        attempted0 = jnp.zeros((F, K), dtype=bool).at[:, 0].set(True)
        cur0 = seq[:, 0]
        if recovery is None:
            curfail0 = fail_seq[:, 0]
            fin0 = t_join + z_seq[:, 0]
        else:
            fin0, curfail0 = fold_chain(
                t_join, z_seq[:, 0], u_err[:, 0], u_jit[:, 0],
                r_bs, r_be, r_cs, r_ce, policy=r_pol, faults=r_fp,
                base_fail=r_base_fail)
    else:
        attempted0 = jnp.zeros((F, K), dtype=bool)
        cur0 = jnp.full((F,), -1)
        curfail0 = jnp.zeros((F,), dtype=bool)
        fin0 = t_join
    if no_failures:
        attempted0 = None         # implied by `done` (see docstring)
    outcome0 = jnp.zeros(K, dtype=bool) if has_cond else None

    def step(carry, _):
        (done, attempted, outcome, cur, curfail, fin, released, trel,
         finished, ok, t_resp) = carry
        t = jnp.min(fin)
        e_hot = jnp.arange(F) == jnp.argmin(fin)
        any_busy = ~jnp.isinf(t)
        task = jnp.sum(jnp.where(e_hot, cur, 0))      # -1 on a join event
        raw_ok = ~jnp.any(curfail & e_hot)
        succ = any_busy & (task >= 0) & raw_ok
        if has_cond:
            # a guard's first finished attempt COMPLETES it either way;
            # the attempt's error bit becomes the recorded branch outcome
            ev_guard = jnp.any((k_ar == task) & c_is_guard)
            succ = succ | (any_busy & (task >= 0) & ev_guard)
            outcome = jnp.where((k_ar == task) & succ, raw_ok, outcome)
        done2 = done | ((k_ar == task) & succ)
        if has_cond:
            # mask-select: cancel the arm gated on the opposite outcome
            cancel = c_gated & done2[c_guard] & (outcome[c_guard] != c_sense)
            done2 = done2 | cancel
        busy = ~jnp.isinf(fin)
        # first-success broadcast preempts peers mid-`task` (§3.3.4)
        preempted = succ & (cur == task) & busy & ~e_hot
        freed = (e_hot & any_busy) | preempted
        busy_after = busy & ~freed
        idle = ~busy_after & ~released
        # next task per member: first in its shifted order neither complete
        # nor already attempted by this member (head-of-line: no skipping);
        # error-free attempts end only because their task completed, so
        # the attempted mask is implied by `done` and statically elided
        cand = (~done2[seq]) if no_failures else (~done2[seq]) & ~attempted
        has_next = jnp.any(cand, axis=1)
        j_hot = k_ar[None, :] == jnp.argmax(cand, axis=1)[:, None]
        nxt = jnp.sum(jnp.where(j_hot, seq, 0), axis=1)
        z_next = jnp.sum(jnp.where(j_hot, z_seq, 0.0), axis=1)
        can_start = idle & has_next
        if has_deps:
            can_start &= ~jnp.any(dep_mask[nxt] & ~done2, axis=1)
        # the finisher chains immediately; preempted/woken members restart
        # after the stream half-RTT
        start = jnp.where(e_hot, t, t + slat)
        if recovery is None:
            f_next = jnp.any(j_hot & fail_seq, axis=1)
            fin_try = start + z_next
        else:
            # the whole timeout/retry/backoff chain is ONE event on the
            # member's placed worker; only the chain's final outcome is
            # visible to peers (§3.3.4)
            u_e = jnp.sum(jnp.where(j_hot[:, :, None], u_err, 0.0),
                          axis=1)
            u_j = jnp.sum(jnp.where(j_hot[:, :, None], u_jit, 0.0),
                          axis=1)
            fin_try, f_next = fold_chain(
                start, z_next, u_e, u_j, r_bs, r_be, r_cs, r_ce,
                policy=r_pol, faults=r_fp, base_fail=r_base_fail)
        fin2 = jnp.where(can_start, fin_try,
                         jnp.where(busy_after, fin, jnp.inf))
        cur2 = jnp.where(can_start, nxt, jnp.where(busy_after, cur, -1))
        curfail2 = jnp.where(can_start, f_next,
                             jnp.where(busy_after, curfail, False))
        attempted2 = (None if no_failures
                      else attempted | (j_hot & can_start[:, None]))
        newly_rel = idle & ~has_next
        released2 = released | newly_rel
        trel2 = jnp.where(newly_rel, t, trel)
        complete = jnp.all(done2)
        no_busy = jnp.all(jnp.isinf(fin2))
        terminal = (complete | no_busy) & ~finished
        trel2 = jnp.where(terminal & ~released2, t, trel2)
        released2 = released2 | terminal
        # no per-element freeze needed past the terminal event: fin is all
        # inf (so t = inf and nothing can start or newly release), done/
        # attempted/released are monotone, and the ok/t_resp outputs latch
        # on `terminal`, which `finished` stops from refiring
        carry2 = (done2, attempted2, outcome, cur2, curfail2, fin2,
                  released2, trel2, finished | terminal,
                  jnp.where(terminal, complete, ok),
                  jnp.where(terminal, t, t_resp))
        return carry2, None

    carry0 = (done0, attempted0, outcome0, cur0, curfail0, fin0, released0,
              trel0, jnp.array(False), jnp.array(False), jnp.array(jnp.inf))
    # F join events (unless direct_start) + at most F*K attempt completions
    steps = (int(num_events) if num_events is not None
             else (F * K if direct_start else F * (K + 1)))
    (_, _, _, _, _, _, _, trel, _, ok, t_resp), _ = lax.scan(
        step, carry0, None, length=steps, unroll=min(steps, 8))
    return t_resp, ok, trel


def _race_f2k2(z_seq, t_join):
    """Closed form of the error-free F=2, K=2 dep-free direct-start race —
    the Table-7/fig6 hot case (keygen, the exponential theory probes).

    With no failures and distinct first tasks there is exactly one event
    sequence shape: the earlier first-attempt completion (``t1``) marks
    its task done and its member chains IMMEDIATELY into the other task
    (start = t1, no stream hop — the finisher chains at the event time);
    the flight then completes at the earlier of the other member's
    first-attempt finish and that chained second attempt, and BOTH
    members release at the terminal event (the loser is preempted by the
    terminal broadcast mid-task, the winner releases on completion).  All
    three operations are the exact adds/selections the generic event scan
    performs, so this is bitwise the scan's result — pinned against the
    ``block=1`` oracle by tests/test_queue_properties.py.
    """
    f_first = t_join + z_seq[:, 0]
    t1 = jnp.min(f_first)
    f_other = jnp.max(f_first)
    e_hot = jnp.arange(2) == jnp.argmin(f_first)
    second = t1 + jnp.sum(jnp.where(e_hot, z_seq[:, 1], 0.0))
    t_resp = jnp.minimum(f_other, second)
    return t_resp, jnp.array(True), jnp.full((2,), t_resp)


# --------------------------------------------------------------------------
# closed-loop trial bodies (one whole arrival stream per trial)
# --------------------------------------------------------------------------

def auto_config(engine: str, scan: str = "auto") -> Tuple[int, str, str]:
    """Default (block, resolver, scan) per engine and backend.

    Measured on the recording box (EXPERIMENTS.md throughput-vs-B table):

    * the chain mode defaults to "seq" on every backend: the log-depth
      associative-summary chain re-resolves every block each outer pass,
      and under bitwise choice coupling the block-level Jacobi gains
      exactly ONE exact block per pass in every load regime
      (EXPERIMENTS.md §log-depth), so the mode is work-bound at >= 2x
      the sequential chain's bookings — an explicit opt-in
      (``scan="logdepth"``), not an auto pick;
    * raptor — bookings are placement-coupled (the chosen worker's AZ
      selects the shared service draws), so fixpoint passes track whole
      intra-block queueing cascades; hosts run fused unrolled blocks of
      8, accelerators the depth-reduced fixpoint;
    * stock — worker identity is interchangeable under ready-sorted
      FCFS, so the order-statistic fixpoint converges in a few passes;
      still, on CPU the sequential oracle already amortizes the dispatch
      cost the fixpoint exists to hide, so it stays default there.

    ``scan`` other than "auto" forces that chain mode and re-resolves
    the (block, resolver) defaults for it; the host log-depth block of
    0 is the adaptive split — ``ceil(n/3)`` at replay build time, two
    Jacobi blocks plus an equal ragged tail, the measured host optimum
    (larger ``nb`` multiplies work by the pass count, smaller wastes
    the tail's single resolve).
    """
    accel = jax.default_backend() not in ("cpu",)
    if scan == "auto":
        scan = "seq"
    if scan == "logdepth":
        return (64, "fixpoint", scan) if accel else (0, "unrolled", scan)
    if engine == "stock":
        return (64, "fixpoint", scan) if accel else (1, "fixpoint", scan)
    return (64, "fixpoint", scan) if accel else (8, "unrolled", scan)


def _raptor_mode(fail_prob: float, faults: FaultProfile,
                 policy: RecoveryPolicy):
    """Resolve the fault-branch statics shared by the whole-trace trial
    builder and the streaming microbatch stepper (one definition, so the
    two paths can never disagree on what flips the fault branch)."""
    fault_mode = ((faults is not None and faults.enabled)
                  or (policy is not None and not policy.is_default))
    pol = policy if policy is not None else NO_RECOVERY
    fp = faults if (faults is not None and faults.enabled) else None
    anyfail = (can_fail(fail_prob, fp, pol) if fault_mode
               else fail_prob > 0.0)
    return fault_mode, pol, fp, anyfail


def _raptor_env(fp: FaultProfile, k_b, k_c, A: int, W: int):
    """Exogenous fault environment: one brownout table per AZ, one crash
    table per worker (policy-only mode rides the inactive [inf, inf)
    sentinels).  Drawn per trial by the whole-trace replay and once per
    stream by the streaming scheduler."""
    if fp is not None:
        bs_az, be_az = fp.brownout_tables(k_b, A)
        cs_w, ce_w = fp.crash_tables(k_c, W)
    else:
        bs_az = be_az = jnp.full((A, 1), jnp.inf)
        cs_w = ce_w = jnp.full((W, 1), jnp.inf)
    return bs_az, be_az, cs_w, ce_w


def _raptor_job_draws(ks, arrivals, *, W, A, F, K, seq, dist, cv, rho,
                      means, offset, stage_oh, oh_mu, oh_sigma, fail_prob,
                      fault_mode, R):
    """Per-job event tensors for one batch of arrivals — the event pytree
    :func:`_raptor_job_body` books, WITHOUT the trial-level fault tables.
    Shared verbatim by the whole-trace trial and the streaming engine's
    per-microbatch draw, so the two paths produce identical event
    distributions by construction."""
    k_s, k_f, k_o, k_p, k_e, k_j = ks
    jobs = arrivals.shape[0]
    # one fused draw for the AZ-shared S block and the private X block
    # (threefry invocations dominate the batch cost on CPU)
    sx = unit_draws(k_s, (jobs, A + F, K), dist, cv)
    s, x = sx[:, :A, :], sx[:, A:, :]
    oh = jnp.exp(oh_mu + oh_sigma * jax.random.normal(k_o, (jobs, F + 1)))
    # member 0 pays the arrival overhead; later members a second
    # control-plane hop (the fork's recursive invocation, §3.3.2)
    t_oh = oh[:, :1] + jnp.where(jnp.arange(F) == 0, 0.0, oh[:, 1:])
    # The service mixture for EVERY possible member->AZ placement is
    # precomputed outside the replay — with the oracle's exact
    # arithmetic order per element, so the hot loop's one-hot row
    # select (an exact selection) leaves the blocked core bitwise the
    # sequential oracle.  (jobs, A, F, K): z_case[j, a, m] = member
    # m's sequence-ordered attempt times were it placed in AZ a.
    z_case = (rho * s[:, :, None, :] + (1 - rho) * x[:, None, :, :]) \
        * means + offset + stage_oh
    z_case = jnp.take_along_axis(
        z_case, jnp.broadcast_to(seq, (jobs, A, F, K)), axis=3)
    # placement tie-break randomness: the scalar sim picks uniformly
    # among the free (fresh-AZ-preferred) workers.  A deterministic
    # earliest-free pick keeps flight release pairs perfectly
    # anti-correlated across AZs and co-location never ignites — the
    # measured high-load colocation rate collapses to 0 vs the scalar
    # sim's ~13%, understating the correlation penalty.  One priority
    # vector per job is enough: members exclude each other's workers,
    # so the conditional pick stays uniform.
    prio = jax.random.uniform(k_p, (jobs, W))
    if fault_mode:
        # fault mode folds base errors into the per-attempt chain
        # uniforms — no precomputed outcome bitmap
        u_err = jax.random.uniform(k_e, (jobs, F, K, R + 1))
        u_jit = jax.random.uniform(k_j, (jobs, F, K, R))
        return (arrivals, z_case, t_oh, prio, u_err, u_jit)
    if fail_prob == 0.0:
        return (arrivals, z_case, t_oh, prio)
    fail = jax.random.bernoulli(k_f, fail_prob, (jobs, F, K))
    fail_seq = jnp.take_along_axis(fail, jnp.broadcast_to(
        seq, (jobs, F, K)), axis=2)
    return (arrivals, z_case, fail_seq, t_oh, prio)


def _raptor_race_budget(block: int, F: int, K: int, anyfail: bool,
                        fault_mode: bool, direct: bool, has_deps: bool):
    """(race_events, closed_form) for the flight race inside the replay.

    With no injected errors every race event is a distinct task
    completion, so K completions (+ the F joins when members cannot
    start mid-attempt) bound the race exactly (dag_flight_trial),
    and the F=2/K=2 dep-free case (the fig6 hot path) close-forms
    entirely (_race_f2k2).  The block=1 oracle path keeps the
    conservative full budget and the generic event scan for every
    workload; the invariance tests prove both reductions against it.
    """
    if block <= 1:
        return None, False
    race_events = (K if not anyfail else F * K) + (0 if direct else F)
    # the closed form knows nothing of inflation/crashes/timeouts,
    # so fault mode always runs the generic event scan
    closed_form = (F == 2 and K == 2 and not anyfail and not fault_mode
                   and direct and not has_deps)
    return race_events, closed_form


def _raptor_job_body(*, W, A, F, w_az, seq, dep_mask, slat, direct,
                     closed_form, race_events, fault_mode, anyfail,
                     fail_prob, pol, fp, has_failseq, env, trace,
                     cond=None):
    """The one-job booking body (HA placement + flight race) the blocked
    substrate replays — extracted from the whole-trace trial so the
    streaming scheduler books each microbatch with the *same* closure
    (bitwise: N microbatched steps carrying the W-state equal one
    whole-trace replay of the concatenated stream).

    ``env`` is the trial/stream-level fault-table bundle from
    :func:`_raptor_env` (``None`` outside fault mode)."""
    if fault_mode:
        bs_az, be_az, cs_w, ce_w = env
        bsW = jnp.take(bs_az, w_az, axis=0)            # (W, I) per worker
        beW = jnp.take(be_az, w_az, axis=0)

    K = seq.shape[1]

    def job_body(wfree, inp):
        if fault_mode:
            arrival, zcj, ohj, prj, u_e, u_j = inp
            fj = jnp.zeros((F, K), dtype=bool)
            # health snapshot at arrival: a worker is healthy iff its
            # AZ is not browned out when the flight places (the scalar
            # sim's _pick_worker_for health tier)
            hw = ~jnp.any((arrival >= bsW) & (arrival < beW), axis=1)
        elif not has_failseq:
            arrival, zcj, ohj, prj = inp
            fj = jnp.zeros((F, K), dtype=bool)
        else:
            arrival, zcj, fj, ohj, prj = inp
        # HA placement (scalar _pick_worker_for + backlog dispatch).
        # Free at arrival: pick a uniform-random free worker in an AZ
        # the flight hasn't used, else a uniform-random free worker.
        # Queued: the member never chooses — it is handed exactly the
        # next-released worker, whatever its AZ.  (Giving a queued
        # member AZ preference among simultaneously-released flight
        # pairs suppresses the scalar sim's ~13% high-load co-location
        # and with it the congestion the paper's Kafka-queue regime
        # shows — see tests/test_sim_queue.py.)
        # one-hot arithmetic only — vmapped dynamic gathers/scatters
        # (w_az[w], used_az.at[az], wf.at[w]) cripple the replay
        wf = wfree
        fresh = jnp.ones(W, dtype=bool)      # workers in unused AZs
        t_disp, widx, m_az = [], [], []
        for m in range(F):
            t_any = jnp.min(wf)
            contended = t_any > arrival
            free = wf <= arrival
            elig = fresh & free
            if fault_mode:
                # health-aware HA: healthy beats fresh beats neither
                # (a browned-out AZ is skipped while ANY healthy free
                # worker exists, and placement degrades gracefully to
                # fewer zones when brownouts leave too few healthy);
                # random-uniform within each tier, like the non-fault
                # ranking below
                key = jnp.where(free, prj + 2.0 * hw + 1.0 * fresh,
                                -1.0)
            else:
                # one argmax: fresh free workers rank in (1, 2], other
                # free in (0, 1], busy at -1 — random-uniform per tier
                key = jnp.where(elig, prj + 1.0,
                                jnp.where(free, prj, -1.0))
            w = jnp.where(contended, jnp.argmin(wf), jnp.argmax(key))
            w_hot = jnp.arange(W) == w
            az = jnp.sum(jnp.where(w_hot, w_az, 0))
            fresh = fresh & (w_az != az)
            t_disp.append(jnp.maximum(arrival, t_any))
            widx.append(w)
            m_az.append(az)
            wf = jnp.where(w_hot, jnp.inf, wf)
        t_disp = jnp.stack(t_disp)
        widx = jnp.stack(widx)
        m_az = jnp.stack(m_az)
        # the AZ-shared S block follows the *actual* placement, so
        # co-located members (queue pressure) re-correlate like the
        # scalar sim; one-hot row select, no in-loop gathers
        az_hot = jnp.arange(A)[:, None] == m_az[None, :]     # (A, F)
        z_seq = jnp.sum(jnp.where(az_hot[:, :, None], zcj, 0.0),
                        axis=0)
        if fault_mode:
            # per-member fault tables follow the actual placement
            # (one-hot row selects — same no-gather discipline as the
            # service mixture above): brownouts of the placed AZ,
            # crashes of the placed worker
            wk_hot = jnp.arange(W)[None, :] == widx[:, None]  # (F, W)
            bs_m = jnp.sum(jnp.where(az_hot[:, :, None],
                                     bs_az[:, None, :], 0.0), axis=0)
            be_m = jnp.sum(jnp.where(az_hot[:, :, None],
                                     be_az[:, None, :], 0.0), axis=0)
            cs_m = jnp.sum(jnp.where(wk_hot[:, :, None],
                                     cs_w[None, :, :], 0.0), axis=1)
            ce_m = jnp.sum(jnp.where(wk_hot[:, :, None],
                                     ce_w[None, :, :], 0.0), axis=1)
            recovery = (pol, fp, fail_prob, bs_m, be_m, cs_m, ce_m,
                        u_e, u_j)
        else:
            recovery = None
        if closed_form:
            t_resp, ok, t_rel = _race_f2k2(z_seq, t_disp + ohj)
        else:
            t_resp, ok, t_rel = dag_flight_trial(
                z_seq, fj, t_disp + ohj, seq, dep_mask, slat,
                direct_start=direct, num_events=race_events,
                no_failures=not anyfail, recovery=recovery, cond=cond)
        # the max-fold into the free-at vector guards the flight-
        # finished-before-dispatch case (the scalar sim skips the
        # dispatch; the worker was never taken); a padded (dead) job
        # must book nothing, so its releases are gated to -inf
        live = ~jnp.isinf(arrival)
        rel = jnp.where(live, t_rel, -jnp.inf)
        out = (t_resp - arrival, ok)
        if trace:
            out = out + (t_disp, widx, t_rel)
        return (widx, rel), out

    return job_body


@functools.lru_cache(maxsize=None)
def _raptor_trial_fn(jobs: int, W: int, A: int, F: int,
                     graph: WorkflowGraph, dist: str,
                     fail_prob: float, faults: FaultProfile = None,
                     policy: RecoveryPolicy = None, block: int = 1,
                     resolver: str = "fixpoint", scan: str = "seq",
                     summary_backend: str = "xla", trace: bool = False):
    """Per-trial closed-loop raptor replay, closed over the compiled IR.

    ``graph`` (a frozen :class:`repro.core.workflow.WorkflowGraph`) IS
    the static manifest key: member sequences, the dependency mask, and
    the conditional select masks all derive from it here, so
    content-equal compiled graphs share one cached executable.

    Traced args: arrival rate, rho, per-task means, offset, cv, stage
    overhead, stream latency, and the Table-6 lognormal (mu, sigma) — so a
    (load x rho) sweep vmaps over configs with one compilation.

    ``block``/``resolver`` chunk the arrival stream through the blocked
    substrate (:func:`repro.sim.scan_core.blocked_event_replay`): the
    fixpoint resolver re-books a whole block as one (block,)-wide batch
    per pass — exact because a job observes earlier jobs only through the
    max-plus worker free-at vector — while the unrolled resolver fuses
    each block into one straight-line region; blocked configs also run
    the races on the tight K-completion event budget.  ``scan``/
    ``summary_backend`` pick how resolved blocks chain ("seq" or the
    associative-summary "logdepth" mode).  ``block=1`` is the sequential
    oracle scan with the conservative full budget, bit-for-bit the
    pre-blocking engine.

    ``trace=True`` additionally returns ``(arrival, dispatch, worker,
    release)`` per (job, member) — the placement/booking trace the
    property-test harness checks worker-occupancy invariants on.

    ``faults``/``policy`` (static, hashable) switch on the fault branch:
    exogenous per-trial brownout/crash interval tables, per-attempt
    policy uniforms, health-aware HA placement, and the chain fold inside
    the race (``dag_flight_trial``'s ``recovery`` bundle).  Both ``None``
    (or disabled/default) compiles EXACTLY the pre-fault path — same key
    splits, same arithmetic, bit-for-bit.

    The draw stage (:func:`_raptor_job_draws`) and the booking body
    (:func:`_raptor_job_body`) are shared with the streaming scheduler
    (:func:`_raptor_stream_fns`), which replays the same body microbatch
    by microbatch on a persistent W-state.
    """
    fault_mode, pol, fp, anyfail = _raptor_mode(fail_prob, faults, policy)
    if not block:
        block = max(1, -(-jobs // 3))   # adaptive log-depth split
    K = graph.K
    seq_np = graph.member_sequences(F)
    seq = jnp.array(seq_np)
    dep_mask = jnp.array(graph.dep_mask())
    cond = graph.cond_static
    w_az = jnp.arange(W) % A
    # members may begin mid-attempt (no join events) only if a late joiner
    # can never find its first task already done while the flight still runs
    direct = (not graph.has_deps
              and len({int(s) for s in seq_np[:, 0]}) == F)
    race_events, closed_form = _raptor_race_budget(
        block, F, K, anyfail, fault_mode, direct, graph.has_deps)

    def trial(key, rate_hz, rho, means, offset, cv, stage_oh, slat,
              oh_mu, oh_sigma):
        if fault_mode:
            (k_a, k_s, k_f, k_o, k_p,
             k_b, k_c, k_e, k_j) = jax.random.split(key, 9)
        else:
            k_a, k_s, k_f, k_o, k_p = jax.random.split(key, 5)
            k_b = k_c = k_e = k_j = None
        arrivals = jnp.cumsum(
            jax.random.exponential(k_a, (jobs,)) * (1000.0 / rate_hz))
        events = _raptor_job_draws(
            (k_s, k_f, k_o, k_p, k_e, k_j), arrivals, W=W, A=A, F=F, K=K,
            seq=seq, dist=dist, cv=cv, rho=rho, means=means, offset=offset,
            stage_oh=stage_oh, oh_mu=oh_mu, oh_sigma=oh_sigma,
            fail_prob=fail_prob, fault_mode=fault_mode, R=pol.max_retries)
        env = _raptor_env(fp, k_b, k_c, A, W) if fault_mode else None
        job_body = _raptor_job_body(
            W=W, A=A, F=F, w_az=w_az, seq=seq, dep_mask=dep_mask, slat=slat,
            direct=direct, closed_form=closed_form, race_events=race_events,
            fault_mode=fault_mode, anyfail=anyfail, fail_prob=fail_prob,
            pol=pol, fp=fp,
            has_failseq=(fail_prob > 0.0 and not fault_mode), env=env,
            trace=trace, cond=cond)
        # no padding: the substrate resolves a ragged tail as one final
        # partial block, so phantom jobs never enter the stream
        _, outs = blocked_event_replay(job_body, jnp.zeros(W), events,
                                       block=block, resolver=resolver,
                                       scan=scan,
                                       summary_backend=summary_backend)
        if trace:
            resp, ok, t_disp, widx, t_rel = outs
            return resp, ok, (arrivals, t_disp, widx, t_rel)
        resp, ok = outs
        return resp, ok

    return trial


@functools.lru_cache(maxsize=None)
def _raptor_stream_fns(W: int, A: int, F: int, graph: WorkflowGraph,
                       dist: str, fail_prob: float,
                       faults: FaultProfile = None,
                       policy: RecoveryPolicy = None, block: int = 1,
                       resolver: str = "fixpoint", scan: str = "seq",
                       summary_backend: str = "xla", trace: bool = False):
    """(draw_env, draw_events, step) for the streaming scheduler service.

    The streaming engine (:mod:`repro.sim.streaming`) runs open arrivals
    against a *persistent* device-resident worker free-at vector: the host
    ingests/draws microbatch ``k+1`` while the device books microbatch
    ``k``, and only the W-vector survives between steps.  All three
    returned functions are jit-able and shape-polymorphic in the
    microbatch length:

    * ``draw_env(key) -> env`` — the stream-level fault-table bundle
      (:func:`_raptor_env`; drawn ONCE per stream — brownout/crash
      interval processes are exogenous wall-clock tables, exactly like
      the whole-trace replay's per-trial draw).  ``None`` outside fault
      mode.
    * ``draw_events(key, arrivals, rho, means, offset, cv, stage_oh,
      oh_mu, oh_sigma) -> events`` — the per-job event tensors for one
      microbatch of (sorted, absolute-ms) arrival times
      (:func:`_raptor_job_draws`, the same draw the whole-trace trial
      performs).  Padded (``inf``) arrivals are dead events: they book
      nothing and leave the W-state bitwise untouched.
    * ``step(wf, events, env, slat) -> (wf', outs)`` — book one
      microbatch through :func:`blocked_event_replay` with the SAME
      booking body as the whole-trace replay.  Because an event observes
      earlier events only through the carried W-vector, N consecutive
      ``step`` calls over slices of a stream are bitwise-identical to one
      whole-trace replay of the concatenated stream (any block/resolver/
      scan config; tests/test_streaming.py pins this on runs AND traces,
      faults on and off).
    """
    fault_mode, pol, fp, anyfail = _raptor_mode(fail_prob, faults, policy)
    K = graph.K
    seq_np = graph.member_sequences(F)
    seq = jnp.array(seq_np)
    dep_mask = jnp.array(graph.dep_mask())
    cond = graph.cond_static
    w_az = jnp.arange(W) % A
    direct = (not graph.has_deps
              and len({int(s) for s in seq_np[:, 0]}) == F)

    def draw_env(key):
        if not fault_mode:
            return None
        k_b, k_c = jax.random.split(key)
        return _raptor_env(fp, k_b, k_c, A, W)

    def draw_events(key, arrivals, rho, means, offset, cv, stage_oh,
                    oh_mu, oh_sigma):
        k_s, k_f, k_o, k_p, k_e, k_j = jax.random.split(key, 6)
        return _raptor_job_draws(
            (k_s, k_f, k_o, k_p, k_e, k_j), arrivals, W=W, A=A, F=F, K=K,
            seq=seq, dist=dist, cv=cv, rho=rho, means=means, offset=offset,
            stage_oh=stage_oh, oh_mu=oh_mu, oh_sigma=oh_sigma,
            fail_prob=fail_prob, fault_mode=fault_mode, R=pol.max_retries)

    def step(wf, events, env, slat):
        mb = int(jax.tree_util.tree_leaves(events)[0].shape[0])
        blk = block if block else max(1, -(-mb // 3))
        race_events, closed_form = _raptor_race_budget(
            blk, F, K, anyfail, fault_mode, direct, graph.has_deps)
        job_body = _raptor_job_body(
            W=W, A=A, F=F, w_az=w_az, seq=seq, dep_mask=dep_mask,
            slat=slat, direct=direct, closed_form=closed_form,
            race_events=race_events, fault_mode=fault_mode,
            anyfail=anyfail, fail_prob=fail_prob, pol=pol, fp=fp,
            has_failseq=(fail_prob > 0.0 and not fault_mode), env=env,
            trace=trace, cond=cond)
        return blocked_event_replay(job_body, wf, events, block=blk,
                                    resolver=resolver, scan=scan,
                                    summary_backend=summary_backend)

    # jit HERE, inside the lru-cached factory: every StreamingScheduler
    # (and every oracle replay) of the same static config shares one
    # compiled executable instead of recompiling per engine instance.
    # The W-buffer is donated — the persistent state updates in place.
    return (draw_env, jax.jit(draw_events),
            jax.jit(step, donate_argnums=0))


@functools.lru_cache(maxsize=None)
def _stock_trial_fn(jobs: int, W: int, A: int, graph: WorkflowGraph,
                    dist: str, fail_prob: float,
                    faults: FaultProfile = None,
                    policy: RecoveryPolicy = None, passes: int = 1,
                    has_extras: bool = False, block: int = 1,
                    backend: str = "scan", resolver: str = "fixpoint",
                    scan: str = "seq",
                    summary_backend: str = "xla", trace: bool = False):
    """Per-trial closed-loop stock replay at TASK granularity (task FCFS).

    The scalar oracle's backlog is one FIFO of *tasks*: a task joins the
    queue the moment its stage hops elapse and takes the next worker, so at
    high load the stages of different jobs interleave freely.  This replay
    reproduces that discipline: all ``jobs * K`` per-task ready-time
    streams are merged into one sorted event stream and the blocked
    substrate books a worker per *task* in ready order (best-fit: the
    worker freed latest but still by the ready time, else the
    earliest-free — both are FCFS-equivalent under ready-sorted
    processing, best-fit keeps earlier idle holes open for the trace).
    ``block`` chunks that stream (``scan_core.stock_booking_fins``: the
    order-statistic fixed point, or the Pallas VMEM kernel when
    ``backend="pallas"``); the trace's final pass resolves worker ids
    through the generic fixed point at the same block size.  ``block=1``
    is bit-for-bit the pre-blocking sequential scan.

    Staged ready times depend on queueing (a map's ready is split's finish)
    so they are materialized by a bounded fixed point over stage depth:
    pass p schedules every task whose depth < p with the ready estimates of
    pass p-1; ``passes = depth + 1`` schedules everything, extra passes
    re-run the schedule with self-consistent estimates (dep-free graphs are
    exact in ONE pass; see ``QueueFlightSim.stock_extra_passes``).

    ``trace=True`` additionally returns ``(arrival, ready, start, fin,
    worker)`` — the booking trace the property-test harness (tests/
    test_queue_properties.py) checks invariants on; ``ready`` is the value
    the final scheduling pass actually honored.

    ``faults``/``policy`` (static, hashable) switch on the fault branch:
    every task expands into ``policy.stock_attempts`` attempt slots
    (primary + retries + the hedge copy), ALL slots join the one merged
    ready-sorted stream (unlaunched slots ride at ``ready = inf`` and
    book nothing), and each booking resolves its outcome against the
    per-trial brownout/crash tables.  Retry/hedge ready times depend on
    earlier bookings, so they materialize through the same bounded fixed
    point that stages already use (``QueueFlightSim`` scales ``passes``
    by the attempt budget).  Attempts reuse the task's service draw
    (deterministic re-execution — ``sim/policies.py``); the trace gains
    an attempt axis plus the per-attempt ``fail`` outcomes.  Both
    ``None`` (or disabled/default) compiles EXACTLY the pre-fault path.
    """
    K = graph.K
    dep_rows = np.array(graph.dep_mask(), dtype=bool)
    has_deps = bool(dep_rows.any())
    root = ~dep_rows.any(axis=1)
    dep_mask = jnp.array(dep_rows)
    root_j = jnp.array(root)
    fault_mode = ((faults is not None and faults.enabled)
                  or (policy is not None and not policy.is_default))
    pol = policy if policy is not None else NO_RECOVERY
    fp = faults if (faults is not None and faults.enabled) else None
    A_att = pol.stock_attempts if fault_mode else 1
    R = pol.max_retries
    N = jobs * K
    Na = N * A_att
    w_az = jnp.arange(W) % A
    if not block:
        block = max(1, -(-Na // 3))     # adaptive log-depth split

    def trial(key, rate_hz, rho, means, extras, offset, cv, stage_oh,
              oh_mu, oh_sigma):
        if fault_mode:
            (k_a, k_z, k_f, k_o,
             k_b, k_c, k_e, k_j) = jax.random.split(key, 8)
        else:
            k_a, k_z, k_f, k_o = jax.random.split(key, 4)
        arrivals = jnp.cumsum(
            jax.random.exponential(k_a, (jobs,)) * (1000.0 / rate_hz))
        # one fused draw for every service mixture (threefry invocations
        # dominate the batch cost on CPU).  Distinct tasks never share an
        # S draw, but each task's time is still the rho-mixture of two
        # i.i.d. draws — same mean, lighter tail than one raw draw (the
        # scalar sim's InvocationDraws.draw); workloads without a second
        # service component (``has_extras``) statically skip its draws.
        zz = unit_draws(k_z, (jobs, 4 if has_extras else 2, K), dist, cv)
        z = (rho * zz[:, 0] + (1 - rho) * zz[:, 1]) * means + offset
        if has_extras:
            z = z + (rho * zz[:, 2] + (1 - rho) * zz[:, 3]) * extras
        if fault_mode:
            ok = None        # derived from the attempt outcomes below
        elif fail_prob == 0.0:
            ok = jnp.ones((jobs,), dtype=bool)
        else:
            ok = ~jnp.any(jax.random.bernoulli(k_f, fail_prob, (jobs, K)),
                          axis=1)
        oh = jnp.exp(oh_mu + oh_sigma * jax.random.normal(k_o,
                                                          (jobs, K + 1)))
        oh0, ohd = oh[:, 0], oh[:, 1:]
        # roots queue after the arrival overhead; staged tasks are inf until
        # a fixed-point pass materializes their dependencies' finish times
        ready0 = jnp.where(root_j[None, :],
                           arrivals[:, None] + oh0[:, None], jnp.inf)
        z_flat = z.reshape(N)
        if fault_mode:
            # exogenous fault environment (policy-only mode rides the
            # inactive sentinels) + per-attempt policy uniforms; the
            # service draw is shared across a task's attempts
            # (deterministic re-execution)
            if fp is not None:
                bs_az, be_az = fp.brownout_tables(k_b, A)
                cs_w, ce_w = fp.crash_tables(k_c, W)
            else:
                bs_az = be_az = jnp.full((A, 1), jnp.inf)
                cs_w = ce_w = jnp.full((W, 1), jnp.inf)
            bsW = jnp.take(bs_az, w_az, axis=0)        # (W, I) per worker
            beW = jnp.take(be_az, w_az, axis=0)
            u_err = jax.random.uniform(k_e, (jobs, K, A_att))
            u_jit = jax.random.uniform(k_j, (jobs, K, R))
            infl = fp.degraded_inflation if fp is not None else 1.0
            pdeg = fp.degraded_fail_prob if fp is not None else fail_prob
            z_att = jnp.broadcast_to(z[:, :, None], (jobs, K, A_att))

        def book(ready, full):
            # ONE merged event stream: every task of every job, ready
            # order.  The sort need not be stable: exact ties only occur
            # among one job's dep-free roots (shared arrival + oh0), whose
            # service draws are i.i.d. symmetric, so the FCFS order among
            # them is statistically irrelevant (the scalar sim pushes them
            # in task-list order).  No padding: the substrate resolves a
            # ragged tail as one final partial block.
            order = jnp.argsort(ready.reshape(N), stable=False)
            r_s = ready.reshape(N)[order]
            z_s = z_flat[order]
            if not full:
                # the stage-depth fixed point only consumes finish times;
                # start/worker are resolved on the trace's final pass (each
                # dropped output is a (jobs*K,) scatter saved per pass)
                fins, = stock_booking_fins(jnp.zeros(W), r_s, z_s,
                                           block=block, backend=backend,
                                           scan=scan,
                                           summary_backend=summary_backend)
                return (jnp.zeros(N).at[order].set(fins[:N])
                        .reshape(jobs, K), None, None)
            fins, sts, wks = blocked_bestfit_booking(
                jnp.zeros(W), r_s, z_s, block=block, full=True,
                backend=backend, scan=scan,
                summary_backend=summary_backend)
            f = jnp.zeros(N).at[order].set(fins[:N]).reshape(jobs, K)
            st = jnp.zeros(N).at[order].set(sts[:N]).reshape(jobs, K)
            wk = jnp.zeros(N, jnp.int32).at[order].set(
                wks[:N]).reshape(jobs, K)
            return f, st, wk

        def refresh(fin):
            # stage hops (storage round-trip + control-plane draw) elapse
            # BEFORE a worker is occupied — FlightSim._stock_enqueue_ready
            dmax = jnp.max(jnp.where(dep_mask[None, :, :],
                                     fin[:, None, :], -jnp.inf), axis=2)
            return jnp.where(root_j[None, :], ready0,
                             dmax + stage_oh + ohd)

        if fault_mode:
            def book_f(att_ready):
                # joint task-FCFS over every attempt slot: one merged
                # ready-sorted stream of jobs*K*A_att events; unlaunched
                # slots ride at ready=inf and book nothing (dead events)
                order = jnp.argsort(att_ready.reshape(Na), stable=False)
                r_s = att_ready.reshape(Na)[order]
                z_s = z_att.reshape(Na)[order]
                u_s = u_err.reshape(Na)[order]

                def att_body(wf, inp):
                    r, zb, u = inp
                    live = ~jnp.isinf(r)
                    # per-worker start were the attempt booked there: the
                    # free-at/ready floor pushed past the worker's crash
                    # outages; earliest start wins, exact ties broken
                    # toward healthy AZs then lowest index — the oracle's
                    # lexicographic (start, degraded, w) dispatch key.  A
                    # flat additive penalty cannot express this in fp32:
                    # at 1e5 ms the spacing is ~8e-3, so any penalty small
                    # enough not to flip genuine orderings is absorbed
                    stw = push_out(jnp.maximum(wf, r), cs_w, ce_w)
                    deg_w = interval_active(stw, bsW, beW)
                    tie = stw == jnp.min(stw)
                    w = jnp.argmin(jnp.where(
                        tie, deg_w.astype(stw.dtype), jnp.inf))
                    w_hot = jnp.arange(W) == w
                    s = jnp.sum(jnp.where(w_hot, stw, 0.0))
                    deg = jnp.any(w_hot & deg_w)
                    zi = zb * jnp.where(deg, infl, 1.0)
                    dur = jnp.minimum(zi, pol.timeout_ms)
                    p_err = jnp.where(deg, pdeg, fail_prob)
                    cs_sel = jnp.sum(jnp.where(w_hot[:, None], cs_w, 0.0),
                                     axis=0)
                    c1 = first_start_in(s, s + dur, cs_sel)
                    crashed = c1 < s + dur
                    end = jnp.where(crashed, c1, s + dur)
                    fl = (u < p_err) | (zi > pol.timeout_ms) | crashed
                    rel = jnp.where(live, end, -jnp.inf)
                    return (w[None], rel[None]), (end, s, fl, w)

                _, outs = blocked_event_replay(
                    att_body, jnp.zeros(W), (r_s, z_s, u_s), block=block,
                    resolver=resolver, scan=scan,
                    summary_backend=summary_backend)
                fins, sts, fls, wks = outs

                def unsort(v, dtype=None):
                    buf = (jnp.zeros(Na) if dtype is None
                           else jnp.zeros(Na, dtype))
                    return (buf.at[order].set(v[:Na])
                            .reshape(jobs, K, A_att))
                return (unsort(fins), unsort(sts), unsort(fls, bool),
                        unsort(wks, jnp.int32))

            def task_outcomes(fin_a, fl_a):
                booked = ~jnp.isinf(fin_a)
                succ = booked & ~fl_a
                any_s = jnp.any(succ, axis=2)
                fin_s = jnp.min(jnp.where(succ, fin_a, jnp.inf), axis=2)
                # a task dies once its retry chain is spent: the LAST
                # chain attempt launched and failed (any launched hedge
                # also failed, else any_s); detection = latest attempt end
                dead = booked[:, :, R] & fl_a[:, :, R]
                fin_d = jnp.max(jnp.where(booked, fin_a, -jnp.inf),
                                axis=2)
                tfin = jnp.where(any_s, fin_s,
                                 jnp.where(dead, fin_d, jnp.inf))
                return tfin, any_s

            def fault_ready(fin_a, st_a, fl_a, base_r):
                # attempt 0 queues at the task's stage ready; retry r
                # queues backoff after attempt r-1's failure; the hedge
                # copy queues hedge_ms after attempt 0 started iff the
                # primary is still running then (outcomes are pre-
                # resolved, so the gate is exact — no cancellation)
                booked = ~jnp.isinf(fin_a)
                cols = [base_r]
                for a in range(1, pol.chain_attempts):
                    prev = booked[:, :, a - 1] & fl_a[:, :, a - 1]
                    back = pol.backoff_ms * (2.0 ** (a - 1)) * (
                        1.0 + pol.backoff_jitter * u_jit[:, :, a - 1])
                    cols.append(jnp.where(
                        prev, fin_a[:, :, a - 1] + back, jnp.inf))
                if pol.has_hedge:
                    st0, fin0 = st_a[:, :, 0], fin_a[:, :, 0]
                    cols.append(jnp.where(
                        booked[:, :, 0] & (fin0 > st0 + pol.hedge_ms),
                        st0 + pol.hedge_ms, jnp.inf))
                return jnp.stack(cols, axis=2)

            att_ready = jnp.concatenate(
                [ready0[:, :, None],
                 jnp.full((jobs, K, A_att - 1), jnp.inf)], axis=2)
            for p in range(passes):
                fin_a, st_a, fl_a, wk_a = book_f(att_ready)
                tfin, any_s = task_outcomes(fin_a, fl_a)
                if p + 1 < passes:
                    base_r = refresh(tfin) if has_deps else ready0
                    att_ready = fault_ready(fin_a, st_a, fl_a, base_r)
            okf = jnp.all(any_s, axis=1)
            resp = jnp.max(tfin, axis=1) - arrivals
            if trace:
                # the drawn fault tables ride along so the property-test
                # harness can check bookings against the outages they
                # were scheduled around
                return resp, okf, (arrivals, att_ready, st_a, fin_a,
                                   wk_a, fl_a, cs_w, ce_w, bs_az, be_az)
            return resp, okf

        ready = ready0
        for p in range(passes):
            fin, start, wkr = book(ready, trace and p + 1 == passes)
            if has_deps and p + 1 < passes:
                ready = refresh(fin)
        resp = jnp.max(fin, axis=1) - arrivals
        if trace:
            return resp, ok, (arrivals, ready, start, fin, wkr)
        return resp, ok

    return trial


@functools.lru_cache(maxsize=None)
def _raptor_runner(jobs, W, A, F, graph, dist, fail_prob,
                   faults: FaultProfile = None,
                   policy: RecoveryPolicy = None,
                   block: int = 1, resolver: str = "fixpoint",
                   scan: str = "seq", summary_backend: str = "xla",
                   trace: bool = False):
    """Jitted (trials,)-vmapped raptor runner, cached so repeated ``run()``
    calls reuse the compiled executable.  Config sweeps no longer live
    here: the device-sharded driver (:mod:`repro.sim.sweeps`) vmaps the
    same per-trial body over the config axis and shards it over the mesh.
    """
    trial = _raptor_trial_fn(jobs, W, A, F, graph, dist,
                             fail_prob, faults, policy, block, resolver,
                             scan, summary_backend, trace)
    return jax.jit(jax.vmap(trial, in_axes=(0,) + (None,) * 9))


@functools.lru_cache(maxsize=None)
def _stock_runner(jobs, W, A, graph, dist, fail_prob,
                  faults: FaultProfile = None,
                  policy: RecoveryPolicy = None, passes: int = 1,
                  has_extras: bool = False, block: int = 1,
                  backend: str = "scan", resolver: str = "fixpoint",
                  scan: str = "seq",
                  summary_backend: str = "xla", trace: bool = False):
    trial = _stock_trial_fn(jobs, W, A, graph, dist, fail_prob,
                            faults, policy, passes, has_extras, block,
                            backend, resolver, scan,
                            summary_backend, trace)
    return jax.jit(jax.vmap(trial, in_axes=(0,) + (None,) * 9))


# --------------------------------------------------------------------------
# public driver
# --------------------------------------------------------------------------

@dataclasses.dataclass
class QueueResult:
    response_ms: jnp.ndarray     # (trials, jobs)
    ok: jnp.ndarray              # (trials, jobs) bool
    raptor: bool

    @property
    def jobs(self) -> int:
        return int(self.response_ms.size)

    def fail_rate(self) -> float:
        return float(1.0 - jnp.mean(self.ok))

    def summary(self) -> dict:
        """Delay summary conditioned on SUCCESS (a failed job's "response"
        is its failure-detection time, not a client-visible delay), with
        the failure accounting alongside: ``n`` counts the successful jobs
        summarized, ``n_failed``/``fail_rate`` the rest."""
        ok = np.asarray(self.ok, dtype=bool).ravel()
        resp = np.asarray(self.response_ms).ravel()[ok]
        if resp.size:
            s = {k: (int(v) if k == "n" else float(v))
                 for k, v in summarize_batch(resp).items()}
        else:
            nan = float("nan")
            s = dict(mean=nan, median=nan, p90=nan, p99=nan, scv=nan, n=0)
        s["fail_rate"] = self.fail_rate()
        s["n_failed"] = int(ok.size - ok.sum())
        return s


class QueueFlightSim:
    """Closed-loop batched Monte-Carlo of one (workload, deployment) pair.

    One *trial* is a whole replication of the queue: ``jobs`` Poisson
    arrivals contending for ``num_workers`` workers spread over ``num_azs``
    AZs, starting empty (like the scalar sim's measurement window).
    """

    def __init__(self, wl: QueueWorkload, *, num_workers: int = 15,
                 num_azs: int = 3, flight: int = None, rho: float = 0.95,
                 load: str = "medium", arrival_rate_hz: float = None,
                 stream_latency_ms: float = 0.5, seed: int = 0,
                 stock_extra_passes: int = 1, block: int = None,
                 resolver: str = "auto", scan: str = "auto",
                 booking_backend: str = "scan",
                 summary_backend: str = "xla",
                 faults: FaultProfile = None,
                 recovery: RecoveryPolicy = None):
        """``stock_extra_passes``: extra fixed-point iterations of the
        task-FCFS stock schedule beyond the ``stage_depth + 1`` needed to
        materialize every ready time.  Dep-free stock graphs (keygen,
        thumbnail) are exact in one pass and ignore this; for staged graphs
        (wordcount) each extra pass re-sorts the merged event stream with
        self-consistent ready estimates — wordcount at util 0.75 already
        sits within ~1% of the scalar oracle at 0 extras and is converged
        at 1 (tests/test_sim_queue.py).

        ``block``/``resolver``/``scan``: the blocked event-replay
        configuration (``sim/scan_core.py``).  Results are block-size,
        resolver, and scan-mode invariant (bitwise —
        tests/test_queue_properties.py), so these are pure performance
        knobs: ``block=None``/``resolver="auto"``/``scan="auto"``
        resolves per engine and backend via :func:`auto_config`;
        ``block=1`` forces the sequential oracle scan (conservative race
        budget — bit-for-bit the pre-blocking engine); larger blocks run
        the chunked substrate with ``resolver`` "fixpoint" (bounded
        parallel fixed point, the depth-reduction mode) or "unrolled"
        (fused sequential chunks), chained either sequentially
        (``scan="seq"``) or through the associative max-plus summary
        prefix (``scan="logdepth"`` — O(log nb) depth per outer Jacobi
        pass; work-bound on hosts, see EXPERIMENTS.md §log-depth).
        ``booking_backend``: "scan" (the jnp substrate) or "pallas" (the
        fused VMEM booking kernel, ``repro.kernels.queue_booking``) for
        the stock stream; ``summary_backend`` routes the log-depth
        summary prefix ("xla" or the ``repro.kernels.maxplus_scan``
        VMEM kernel).

        ``faults``/``recovery``: the fault environment
        (:class:`repro.sim.faults.FaultProfile`) and attempt-level
        policy (:class:`repro.sim.policies.RecoveryPolicy`); ``None``
        defaults from the workload's own fields, explicit kwargs win.
        An enabled profile or non-default policy flips both engines onto
        the fault branch (still block/resolver/scan invariant, bitwise);
        it is incompatible with ``booking_backend="pallas"``, whose
        fused kernel books plain FCFS finishes only."""
        self.wl = wl
        self.W = int(num_workers)
        self.A = int(num_azs)
        self.flight = int(flight if flight is not None else wl.flight)
        if self.flight > self.W:
            # the placement loop hands each member a distinct worker; more
            # members than workers would dispatch at argmin(all-inf) = inf
            raise ValueError(
                f"flight={self.flight} needs distinct workers but the "
                f"deployment has only num_workers={self.W}")
        self.rho = float(rho)
        self.load = load
        self.slat = float(stream_latency_ms)
        self.seed = int(seed)
        self.rate_hz = float(
            arrival_rate_hz if arrival_rate_hz is not None
            else _rate_for_load(wl.work_est_ws, self.W, load))
        # offered utilisation (service work / capacity), for reference and
        # for sizing windows; the substrate config resolves per engine
        self.utilization = self.rate_hz * wl.work_est_ws / self.W
        self._block = None if block is None else int(block)
        self.resolver = str(resolver)
        self.scan = str(scan)
        self.booking_backend = str(booking_backend)
        self.summary_backend = str(summary_backend)
        self.faults = faults if faults is not None else wl.faults
        self.recovery = (recovery if recovery is not None
                         else (wl.recovery if wl.recovery is not None
                               else NO_RECOVERY))
        # statics handed to the cached trial builders: None unless they
        # change behavior, so disabled profiles share the pre-fault
        # compile cache entries (and their bitwise output)
        self._fp = (self.faults if (self.faults is not None
                                    and self.faults.enabled) else None)
        self.fault_mode = (self._fp is not None
                           or not self.recovery.is_default)
        self._policy = self.recovery if self.fault_mode else None
        if self.fault_mode and self.booking_backend == "pallas":
            raise ValueError(
                "booking_backend='pallas' books plain FCFS finish times "
                "only; fault injection needs the generic scan substrate")
        ha = self.A > 1
        self.oh_mu, self.oh_sigma = lognormal_params(
            *OverheadModel.TABLE[(ha, load)])
        # static manifest prep: both engines' sequences/masks/levels now
        # come straight off the compiled IR (repro.core.workflow) — the
        # graph objects themselves are the cached builders' static keys
        self._sgraph = wl.stock_graph()
        self._smeans = np.asarray(self._sgraph.means, dtype=np.float32)
        self._sextras = np.asarray(wl.stock_extras(), dtype=np.float32)
        # fixed-point pass budget for the task-FCFS stock replay: depth+1
        # passes materialize every ready time, extras refine the estimates
        self._sdepth = self._sgraph.stage_depth()
        if self.fault_mode:
            # the retry/hedge readies materialize through the same
            # bounded fixed point as staged readies: each stage level
            # needs its whole attempt chain resolved before dependents'
            # estimates settle, so the pass budget scales by the
            # per-task attempt count
            self._spasses = ((self._sdepth + 1)
                             * self.recovery.stock_attempts
                             + int(stock_extra_passes))
        else:
            self._spasses = (1 if self._sdepth == 0
                             else self._sdepth + 1
                             + int(stock_extra_passes))

    # -- compiled runners ------------------------------------------------
    def engine_config(self, engine: str) -> Tuple[int, str, str]:
        """Resolved (block, resolver, scan) for ``engine``
        ("raptor"/"stock"): explicit constructor knobs win, the rest
        comes from :func:`auto_config`'s measured per-backend policy
        (forcing ``scan`` re-resolves the defaults for that chain mode)."""
        blk, res, sc = auto_config(engine, self.scan)
        if self._block is not None:
            blk = self._block
        if self.resolver != "auto":
            res = self.resolver
        return blk, res, sc

    def _raptor_fn(self, jobs: int, trace: bool = False):
        blk, res, sc = self.engine_config("raptor")
        return _raptor_runner(
            int(jobs), self.W, self.A, self.flight, self.wl.graph,
            self.wl.dist, self.wl.fail_prob, self._fp, self._policy,
            blk, res, sc, self.summary_backend, trace)

    def _stock_fn(self, jobs: int, trace: bool = False):
        blk, res, sc = self.engine_config("stock")
        return _stock_runner(
            int(jobs), self.W, self.A, self._sgraph,
            self.wl.dist, self.wl.fail_prob, self._fp, self._policy,
            self._spasses, bool(self._sextras.any()), blk,
            self.booking_backend, res, sc, self.summary_backend, trace)

    def _raptor_args(self):
        wl = self.wl
        return (self.rate_hz, self.rho,
                jnp.asarray(wl.task_means, dtype=jnp.float32), wl.offset_ms,
                wl.cv, wl.raptor_stage_ms, self.slat,
                self.oh_mu, self.oh_sigma)

    def _stock_args(self):
        wl = self.wl
        return (self.rate_hz, self.rho, jnp.asarray(self._smeans),
                jnp.asarray(self._sextras), wl.offset_ms, wl.cv,
                wl.stock_stage_ms, self.oh_mu, self.oh_sigma)

    def _keys(self, trials: int, raptor: bool):
        base = jax.random.PRNGKey(self.seed * 2 + (1 if raptor else 0))
        return jax.random.split(base, trials)

    def run(self, jobs: int = 1024, trials: int = 16, *,
            raptor: bool = True) -> QueueResult:
        if raptor:
            fn = self._raptor_fn(jobs)
            resp, ok = fn(self._keys(trials, True), *self._raptor_args())
        else:
            fn = self._stock_fn(jobs)
            resp, ok = fn(self._keys(trials, False), *self._stock_args())
        return QueueResult(resp, ok, raptor)

    def run_pair(self, jobs: int = 1024, trials: int = 16) -> Dict[str, dict]:
        stock = self.run(jobs, trials, raptor=False)
        rap = self.run(jobs, trials, raptor=True)
        out = {"stock": stock.summary(), "raptor": rap.summary()}
        out["mean_ratio"] = out["raptor"]["mean"] / out["stock"]["mean"]
        return out

    def trace_run(self, jobs: int = 256, trials: int = 4, *,
                  raptor: bool = True) -> Dict[str, np.ndarray]:
        """Replay with the booking trace exposed (host numpy arrays).

        Stock: per-(trial, job, task) ``ready`` (the value the final
        scheduling pass honored), ``start``, ``fin``, ``worker``.  Raptor:
        per-(trial, job, member) ``dispatch``/``worker``/``release`` — the
        worker-occupancy intervals.  The property-test harness
        (tests/test_queue_properties.py) checks queue invariants on these;
        same seeds as :meth:`run`, so the traced replay IS the measured
        one.
        """
        if raptor:
            fn = self._raptor_fn(jobs, trace=True)
            resp, ok, (arr, disp, widx, rel) = fn(
                self._keys(trials, True), *self._raptor_args())
            return {"response": np.asarray(resp), "ok": np.asarray(ok),
                    "arrival": np.asarray(arr),
                    "dispatch": np.asarray(disp),
                    "worker": np.asarray(widx),
                    "release": np.asarray(rel)}
        fn = self._stock_fn(jobs, trace=True)
        if self.fault_mode:
            # fault-mode stock traces carry the attempt axis (jobs, K,
            # A_att) plus the per-attempt failure outcomes; an unlaunched
            # attempt slot shows ready/start/fin = inf.  The per-trial
            # fault tables ((W, C) crash and (A, I) brownout intervals)
            # ride along for outage-aware invariant checks.
            resp, ok, (arr, ready, start, fin, wkr, fl,
                       cs, ce, bs, be) = fn(
                self._keys(trials, False), *self._stock_args())
            return {"response": np.asarray(resp), "ok": np.asarray(ok),
                    "arrival": np.asarray(arr),
                    "ready": np.asarray(ready),
                    "start": np.asarray(start), "fin": np.asarray(fin),
                    "worker": np.asarray(wkr), "fail": np.asarray(fl),
                    "crash_start": np.asarray(cs),
                    "crash_end": np.asarray(ce),
                    "az_start": np.asarray(bs), "az_end": np.asarray(be)}
        resp, ok, (arr, ready, start, fin, wkr) = fn(
            self._keys(trials, False), *self._stock_args())
        return {"response": np.asarray(resp), "ok": np.asarray(ok),
                "arrival": np.asarray(arr), "ready": np.asarray(ready),
                "start": np.asarray(start), "fin": np.asarray(fin),
                "worker": np.asarray(wkr)}


# --------------------------------------------------------------------------
# batched config sweeps: thin plans over the device-sharded driver
# --------------------------------------------------------------------------
# Arrival rate and the Table-6 overhead lognormal are traced, so the config
# axis is pure batching; repro.sim.sweeps vmaps it and shards it over the
# device mesh (bit-identical to the single-device run) — adding a point
# costs milliseconds, not a recompile, and a multi-device host runs the
# grid near-linearly faster (BENCH_sim.json sweep_sharded).

def load_sweep(wl: QueueWorkload, *, num_workers: int = 15, num_azs: int = 3,
               loads=("low", "medium", "high"), rho: float = 0.95,
               jobs: int = 1024, trials: int = 16,
               seed: int = 0, devices=None) -> Dict[str, dict]:
    """All Table-6 load points of one deployment, one compile per mode."""
    from repro.sim.sweeps import queue_pair_plan
    sims = [QueueFlightSim(wl, num_workers=num_workers, num_azs=num_azs,
                           load=load, rho=rho, seed=seed) for load in loads]
    return dict(zip(loads,
                    queue_pair_plan(sims, jobs, trials).run(devices=devices)))


def rate_sweep(wl: QueueWorkload, rates_hz, *, loads=None,
               num_workers: int = 15, num_azs: int = 3, rho: float = 0.95,
               jobs: int = 1024, trials: int = 16, seed: int = 0,
               devices=None):
    """Arbitrary arrival-rate grid (continuous load axis) on one
    deployment; ``loads`` optionally names the Table-6 overhead regime per
    point (defaults to "medium").  Returns one pair dict per rate."""
    from repro.sim.sweeps import queue_pair_plan
    loads = list(loads) if loads is not None else ["medium"] * len(rates_hz)
    sims = [QueueFlightSim(wl, num_workers=num_workers, num_azs=num_azs,
                           load=load, rho=rho, arrival_rate_hz=float(r),
                           seed=seed)
            for r, load in zip(rates_hz, loads)]
    return queue_pair_plan(sims, jobs, trials).run(devices=devices)
