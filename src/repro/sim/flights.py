"""Queueing + flight simulator: stock OpenWhisk fork-join vs Raptor flights
on a worker cluster, with Poisson arrivals, preemption, and work accounting.

Stock mode: a job's tasks queue independently FCFS for workers as their
dependencies complete; each inter-stage hop pays the control-plane overhead
plus any storage round-trip (``stock_stage_overhead``); the job completes
when all tasks do (fork-join).

Raptor mode: a job is one flight of ``concurrency`` members over distinct
workers (HA placement spreads them across AZs).  Members run the manifest
in cyclically shifted order (§3.3.3), skip tasks whose first completion has
been broadcast, and are preempted mid-task when a peer finishes first —
their worker is freed after the half-RTT stream latency (§3.3.4).  Member
task failures degrade the flight; the job fails only if every member fails
(Figure 8's p^N).

Job-accounting conventions (shared with the vectorized engines so
agreement tests compare like with like — see sim/vector_queue.py):

* horizon drain: arrivals stop at ``duration_s`` but the event queue
  drains past it, so jobs still in flight at the horizon run to
  completion instead of being censored (dropping them biases the
  high-load tails low — the in-flight jobs are exactly the slow ones);
* dependency waits are event-driven: a member whose next task has an
  unmet dependency parks and is re-woken one stream half-RTT after the
  unblocking completion broadcast (any ``stream_latency_ms`` >= 0 is
  honored exactly — there is no poll floor);
* a flight that can never progress (every attempt of some dependency
  errored) terminates with ``ok=False`` at its last event, so every
  admitted job is returned, successful or not.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List

import numpy as np

from repro.sim.cluster import Cluster
from repro.sim.events import EventQueue


@dataclasses.dataclass
class SimWorkload:
    """Service-time model of one manifest."""
    name: str
    tasks: List[str]
    deps: Dict[str, tuple]
    concurrency: int
    make_draws: Callable                 # cluster -> InvocationDraws
    stock_stage_overhead: float = 0.0    # storage/requeue per stage hop (ms)
    raptor_stage_overhead: float = 0.5   # stream hop (ms)
    fail_prob: float = 0.0
    work_est_ws: float = 2.0             # worker-seconds/job (load targeting)
    # optional alternative task graph for the STOCK path (workloads whose
    # stock functions are self-contained, e.g. thumbnail re-downloads)
    stock_tasks: List[str] = None
    stock_deps: Dict[str, tuple] = None

    @property
    def stock_task_list(self):
        return self.stock_tasks if self.stock_tasks is not None else self.tasks

    @property
    def stock_dep_map(self):
        return self.stock_deps if self.stock_deps is not None else self.deps


@dataclasses.dataclass
class JobRecord:
    t_arrive: float
    t_done: float = -1.0
    ok: bool = True
    work_ms: float = 0.0

    @property
    def response(self) -> float:
        return self.t_done - self.t_arrive


class FlightSim:
    def __init__(self, cluster: Cluster, wl: SimWorkload, *, raptor: bool,
                 arrival_rate_hz: float, duration_s: float = 1800.0,
                 load: str = "medium", stream_latency_ms: float = 0.5,
                 seed: int = 0, rotate: bool = True):
        """rotate=True (default) uses the paper's §3.3.3 cyclic-shift
        sequences — essential for parallelizable DAGs (racing one shared
        order serialises them).  rotate=False has all members race the same
        sequence, the dynamics the paper's §4.2.1 2*E[min]/E[max] equation
        actually describes (see EXPERIMENTS.md for the measured gap)."""
        self.cl = cluster
        self.wl = wl
        self.raptor = raptor
        self.lam = arrival_rate_hz
        self.duration_ms = duration_s * 1000
        self.load = load
        self.slat = stream_latency_ms
        self.rng = np.random.default_rng(seed + 1)
        self.q = EventQueue()
        self.free = set(range(cluster.num_workers))
        self.backlog: List = []
        self.jobs: List[JobRecord] = []
        n_seq = max(wl.concurrency, 1) if rotate else 1
        self._seqs = [self._exec_sequence(i) for i in range(n_seq)]

    # ------------------------------------------------------------------
    def run(self) -> List[JobRecord]:
        """Replay the arrival stream; returns ONE record per admitted job.

        Horizon-drain semantics: arrivals stop at the horizon, but the
        event queue drains past it so every admitted job runs to
        completion — nothing is censored.  Flights that can never progress
        (deadlocked on errored dependencies) fail at their last event
        (``_check_deadlock``); the rare cross-flight stall — parked
        members of partially-joined flights holding every worker — is
        resolved after the drain by failing the stuck jobs at the stall
        instant rather than silently dropping them.
        """
        t = float(self.rng.exponential(1000.0 / self.lam))
        while t < self.duration_ms:
            self.q.schedule(t, self._arrive)
            t += float(self.rng.exponential(1000.0 / self.lam))
        self.q.run()
        for j in self.jobs:
            if j.t_done < 0:
                j.t_done = self.q.now
                j.ok = False
        return self.jobs

    def _arrive(self):
        rec = JobRecord(t_arrive=self.q.now)
        self.jobs.append(rec)
        overhead = float(self.cl.sample_overhead(self.load, 1)[0])
        draws = self.wl.make_draws(self.cl)
        draws.raptor = self.raptor
        if self.raptor:
            fl = {
                "rec": rec, "members": [], "draws": draws,
                "ptr": {}, "seq_idx": {},
                "done": {}, "running": {},
                "released": set(), "failed_members": set(),
                "n_members": 0,
                # event-driven dependency waits + deadlock detection
                "parked": set(), "done_members": set(), "pending": 0,
            }
            for m in range(max(self.wl.concurrency, 1)):
                oh = overhead if m == 0 else overhead + float(
                    self.cl.sample_overhead(self.load, 1)[0])
                self.backlog.append(("member", fl, m, oh))
            self._dispatch()
        else:
            state = {"rec": rec, "done": set(), "queued": set(),
                     "draws": draws}
            self._stock_enqueue_ready(state, overhead)

    def _ready(self, done: set) -> List[str]:
        return [t for t in self.wl.stock_task_list
                if t not in done
                and all(d in done for d in self.wl.stock_dep_map[t])]

    def _stock_enqueue_ready(self, state, overhead):
        """Stage hops (control plane + storage round-trips) elapse BEFORE a
        worker is occupied — they are control-path delays, not service."""
        for task in self._ready(state["done"]):
            if task not in state["queued"]:
                state["queued"].add(task)
                self.q.schedule(self.q.now + overhead, self._stock_push,
                                state, task)

    def _stock_push(self, state, task):
        self.backlog.append(("task", state["rec"], task, state))
        self._dispatch()

    # ------------------------------------------------------------------
    def _dispatch(self):
        while self.backlog and self.free:
            kind = self.backlog[0][0]
            if kind == "task":
                _, rec, task, state = self.backlog.pop(0)
                w = self.free.pop()
                svc = state["draws"].draw(task, w)
                fail = self.rng.random() < self.wl.fail_prob
                self.q.schedule(self.q.now + svc,
                                self._stock_finish, rec, state, task, w,
                                fail, svc)
            else:
                # one flight MEMBER (paper §3.3.2: the fork's recursive
                # invocations queue independently and join the stream late)
                _, fl, member_idx, overhead = self.backlog.pop(0)
                if fl["rec"].t_done >= 0:
                    continue                      # flight already finished
                w = self._pick_worker_for(fl)
                self.free.discard(w)
                self._join_member(fl, w, member_idx, overhead)

    def _pick_worker_for(self, fl) -> int:
        """HA-aware pick: prefer AZs not yet used by this flight."""
        used_azs = {int(self.cl.az_of[w]) for w in fl["members"]}
        fresh = [w for w in self.free
                 if int(self.cl.az_of[w]) not in used_azs]
        pool = fresh if fresh else list(self.free)
        return pool[int(self.rng.integers(len(pool)))]

    # ------------------------------------------------------------------
    # stock OpenWhisk fork-join
    def _stock_finish(self, rec, state, task, worker, fail, svc):
        self.free.add(worker)
        rec.work_ms += svc
        if fail:
            rec.ok = False
        state["done"].add(task)
        oh = self.wl.stock_stage_overhead + float(
            self.cl.sample_overhead(self.load, 1)[0])
        self._stock_enqueue_ready(state, oh)
        if len(state["done"]) == len(self.wl.stock_task_list):
            rec.t_done = self.q.now
        self._dispatch()

    # ------------------------------------------------------------------
    # Raptor flight
    def _join_member(self, fl, w: int, member_idx: int, overhead: float):
        fl["members"].append(w)
        fl["seq_idx"][w] = member_idx % len(self._seqs)
        fl["ptr"][w] = 0
        fl["n_members"] += 1
        self._wake(fl, w, overhead)

    def _wake(self, fl, w, delay: float):
        """Schedule a member continuation, counted in ``fl["pending"]`` so
        deadlock detection can tell 'quiescent' from 'wake in flight'."""
        fl["pending"] += 1
        self.q.schedule(self.q.now + delay, self._member_wake, fl, w)

    def _member_wake(self, fl, w):
        fl["pending"] -= 1
        self._member_next(fl, w)

    def _check_deadlock(self, fl):
        """Fail the flight the moment no member can ever progress: every
        joined member parked on an unmet dependency or out of tasks, no
        attempt running, no wake pending, and the whole flight joined.
        (Without this, members parked on a dependency whose every attempt
        errored would wait forever and the event queue would never drain —
        the job could not even be *observed* as censored.)  Subsumes the
        old every-member-exhausted check: that is the ``parked``-empty
        special case."""
        if (fl["rec"].t_done < 0 and not fl["running"]
                and fl["pending"] == 0
                and fl["n_members"] >= max(self.wl.concurrency, 1)
                and len(fl["parked"]) + len(fl["done_members"])
                >= fl["n_members"]
                and len(fl["done"]) < len(self.wl.tasks)):
            fl["rec"].t_done = self.q.now
            fl["rec"].ok = False
            self._finish_flight(fl)

    def _exec_sequence(self, index: int) -> List[str]:
        from repro.core.dag import execution_sequence
        from repro.core.manifest import ActionManifest, FunctionSpec
        man = ActionManifest(
            tuple(FunctionSpec(t, None, tuple(self.wl.deps[t]))
                  for t in self.wl.tasks),
            concurrency=max(self.wl.concurrency, 1), name=self.wl.name)
        return execution_sequence(man, index)

    def _member_next(self, fl, w):
        if fl["rec"].t_done >= 0 or w in fl["released"]:
            return
        seq = self._seqs[fl["seq_idx"][w]]
        ptr = fl["ptr"][w]
        while ptr < len(seq):
            task = seq[ptr]
            if task in fl["done"]:
                ptr += 1
                continue
            if all(d in fl["done"] for d in self.wl.deps[task]):
                break
            # dependency not yet visible on the stream: park until a
            # completion broadcast re-wakes us half an RTT later.  Event-
            # driven, not polled — the old max(slat, 0.1)ms poll both
            # busy-polled and quantized sub-0.1ms stream latencies away
            # from the vector scan's exact broadcast+slat wake.
            fl["ptr"][w] = ptr
            fl["parked"].add(w)
            self._check_deadlock(fl)
            return
        fl["ptr"][w] = ptr
        if ptr >= len(seq):
            # member exhausted its sequence; the job fails once NO member
            # can make progress with tasks still incomplete (all attempts
            # of some task errored) — _check_deadlock's terminal case
            fl["done_members"].add(w)
            self._release_member(fl, w)
            self._check_deadlock(fl)
            return
        task = seq[ptr]
        svc = fl["draws"].draw(task, w)
        fail = self.rng.random() < self.wl.fail_prob
        eid = self.q.schedule(
            self.q.now + svc + self.wl.raptor_stage_overhead,
            self._member_finish, fl, w, task, fail, self.q.now)
        fl["running"][w] = (task, eid, self.q.now)

    def _member_finish(self, fl, w, task, fail, t0):
        fl["running"].pop(w, None)
        fl["rec"].work_ms += self.q.now - t0
        fl["ptr"][w] += 1
        if fail:
            # §3.3.4: the error event is broadcast and IGNORED by peers; the
            # member moves on.  The task stays pending for other members.
            fl["failed_members"].add(w)
            self._wake(fl, w, 0.0)
            return
        if task not in fl["done"]:
            fl["done"][task] = self.q.now
            # broadcast: preempt peers running `task` (half-RTT delivery)
            for pw, (ptask, eid, pt0) in list(fl["running"].items()):
                if ptask == task:
                    self.q.cancel(eid)
                    fl["running"].pop(pw)
                    fl["rec"].work_ms += (self.q.now + self.slat) - pt0
                    fl["ptr"][pw] += 0
                    self._wake(fl, pw, self.slat)
            # ...and wake members parked on a dependency: they re-check
            # their head-of-line task half an RTT after the broadcast
            # (re-parking if still blocked) — the vector scan's semantics
            for pw in list(fl["parked"]):
                fl["parked"].discard(pw)
                self._wake(fl, pw, self.slat)
        if len(fl["done"]) == len(self.wl.tasks):
            fl["rec"].t_done = self.q.now
            fl["rec"].ok = True
            self._finish_flight(fl)
            return
        self._wake(fl, w, 0.0)

    def _finish_flight(self, fl):
        for pw, (ptask, eid, pt0) in list(fl["running"].items()):
            self.q.cancel(eid)
            fl["rec"].work_ms += self.q.now - pt0
            fl["running"].pop(pw)
        for pw in fl["members"]:
            self._release_member(fl, pw)

    def _release_member(self, fl, w):
        if w not in fl["released"]:
            fl["released"].add(w)
            self.free.add(w)
            self._dispatch()
