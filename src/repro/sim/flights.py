"""Queueing + flight simulator: stock OpenWhisk fork-join vs Raptor flights
on a worker cluster, with Poisson arrivals, preemption, and work accounting.

Stock mode: a job's tasks queue independently FCFS for workers as their
dependencies complete; each inter-stage hop pays the control-plane overhead
plus any storage round-trip (``stock_stage_overhead``); the job completes
when all tasks do (fork-join).

Raptor mode: a job is one flight of ``concurrency`` members over distinct
workers (HA placement spreads them across AZs).  Members run the manifest
in cyclically shifted order (§3.3.3), skip tasks whose first completion has
been broadcast, and are preempted mid-task when a peer finishes first —
their worker is freed after the half-RTT stream latency (§3.3.4).  Member
task failures degrade the flight; the job fails only if every member fails
(Figure 8's p^N).

Job-accounting conventions (shared with the vectorized engines so
agreement tests compare like with like — see sim/vector_queue.py):

* horizon drain: arrivals stop at ``duration_s`` but the event queue
  drains past it, so jobs still in flight at the horizon run to
  completion instead of being censored (dropping them biases the
  high-load tails low — the in-flight jobs are exactly the slow ones);
* dependency waits are event-driven: a member whose next task has an
  unmet dependency parks and is re-woken one stream half-RTT after the
  unblocking completion broadcast (any ``stream_latency_ms`` >= 0 is
  honored exactly — there is no poll floor);
* a flight that can never progress (every attempt of some dependency
  errored) terminates with ``ok=False`` at its last event, so every
  admitted job is returned, successful or not.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.core.workflow import WorkflowGraph
from repro.sim.cluster import Cluster
from repro.sim.events import EventQueue
from repro.sim.faults import FaultProfile, interval_active_np
from repro.sim.policies import (NO_RECOVERY, RecoveryPolicy,
                                attempt_outcome_np, fold_chain_np,
                                push_out_np)


@dataclasses.dataclass
class SimWorkload:
    """Service-time model of one compiled manifest.

    ``graph`` is the workflow compiler's IR (:mod:`repro.core.workflow`)
    — the SAME object the vectorized engines key their compiled trial
    builders on, so scalar/vector pairs can never disagree on the DAG.
    """
    graph: WorkflowGraph
    concurrency: int
    make_draws: Callable                 # cluster -> InvocationDraws
    stock_stage_overhead: float = 0.0    # storage/requeue per stage hop (ms)
    raptor_stage_overhead: float = 0.5   # stream hop (ms)
    fail_prob: float = 0.0
    work_est_ws: float = 2.0             # worker-seconds/job (load targeting)
    # optional alternative graph for the STOCK path (workloads whose stock
    # functions are self-contained, e.g. thumbnail re-downloads); default
    # is the flight graph with conditionals flattened — the stock baseline
    # has no data-dependent short-circuiting
    stock: Optional[WorkflowGraph] = None
    # fault environment + recovery policy carried with the workload so a
    # scalar/vector pair built from the same object injects identically
    # (sim/faults.py, sim/policies.py); constructor kwargs override
    faults: Optional[FaultProfile] = None
    recovery: Optional[RecoveryPolicy] = None

    @property
    def name(self) -> str:
        return self.graph.name

    @property
    def stock_graph(self) -> WorkflowGraph:
        return self.stock if self.stock is not None else self.graph.flatten()


@dataclasses.dataclass
class JobRecord:
    t_arrive: float
    t_done: float = -1.0
    ok: bool = True
    work_ms: float = 0.0

    @property
    def response(self) -> float:
        return self.t_done - self.t_arrive


class FlightSim:
    def __init__(self, cluster: Cluster, wl: SimWorkload, *, raptor: bool,
                 arrival_rate_hz: float, duration_s: float = 1800.0,
                 load: str = "medium", stream_latency_ms: float = 0.5,
                 seed: int = 0, rotate: bool = True,
                 faults: FaultProfile = None,
                 recovery: RecoveryPolicy = None):
        """rotate=True (default) uses the paper's §3.3.3 cyclic-shift
        sequences — essential for parallelizable DAGs (racing one shared
        order serialises them).  rotate=False has all members race the same
        sequence, the dynamics the paper's §4.2.1 2*E[min]/E[max] equation
        actually describes (see EXPERIMENTS.md for the measured gap)."""
        self.cl = cluster
        self.wl = wl
        self.raptor = raptor
        self.lam = arrival_rate_hz
        self.duration_ms = duration_s * 1000
        self.load = load
        self.slat = stream_latency_ms
        self.rng = np.random.default_rng(seed + 1)
        self.q = EventQueue()
        self.free = set(range(cluster.num_workers))
        self.backlog: List = []
        self.jobs: List[JobRecord] = []
        # cached views of the compiled IR (the hot loops index these)
        self._deps = wl.graph.dep_map()
        self._K = wl.graph.K
        sg = wl.stock_graph
        self._stock_tasks = list(sg.tasks)
        self._stock_deps = sg.dep_map()
        # conditional select masks: guard name -> [(task, sense), ...]
        self._guards: Dict[str, list] = {}
        for t, g, s in zip(wl.graph.tasks, wl.graph.cond_guard,
                           wl.graph.cond_sense):
            if g >= 0:
                self._guards.setdefault(wl.graph.tasks[g], []).append((t, s))
        n_seq = max(wl.concurrency, 1) if rotate else 1
        self._seqs = [self._exec_sequence(i) for i in range(n_seq)]
        # fault environment + recovery policy (sim/faults.py, sim/
        # policies.py): explicit kwargs win, else whatever the workload
        # carries.  Tables come from a dedicated rng stream so enabling
        # faults does not perturb the service/arrival draws.
        fp = faults if faults is not None else wl.faults
        self.fp = fp if (fp is not None and fp.enabled) else None
        pol = recovery if recovery is not None else wl.recovery
        self.policy = pol if pol is not None else NO_RECOVERY
        self.fault_mode = self.fp is not None or not self.policy.is_default
        frng = np.random.default_rng(seed + 7919)
        if self.fp is not None:
            self._bs, self._be = self.fp.brownout_tables_np(
                frng, cluster.num_azs)
            self._cs, self._ce = self.fp.crash_tables_np(
                frng, cluster.num_workers)
        else:                         # policy-only mode: healthy sentinels
            self._bs = np.full((cluster.num_azs, 1), np.inf)
            self._be = self._bs
            self._cs = np.full((cluster.num_workers, 1), np.inf)
            self._ce = self._cs

    # ------------------------------------------------------------------
    def run(self) -> List[JobRecord]:
        """Replay the arrival stream; returns ONE record per admitted job.

        Horizon-drain semantics: arrivals stop at the horizon, but the
        event queue drains past it so every admitted job runs to
        completion — nothing is censored.  Flights that can never progress
        (deadlocked on errored dependencies) fail at their last event
        (``_check_deadlock``); the rare cross-flight stall — parked
        members of partially-joined flights holding every worker — is
        resolved after the drain by failing the stuck jobs at the stall
        instant rather than silently dropping them.
        """
        t = float(self.rng.exponential(1000.0 / self.lam))
        while t < self.duration_ms:
            self.q.schedule(t, self._arrive)
            t += float(self.rng.exponential(1000.0 / self.lam))
        self.q.run()
        for j in self.jobs:
            if j.t_done < 0:
                j.t_done = self.q.now
                j.ok = False
        return self.jobs

    def _arrive(self):
        rec = JobRecord(t_arrive=self.q.now)
        self.jobs.append(rec)
        overhead = float(self.cl.sample_overhead(self.load, 1)[0])
        draws = self.wl.make_draws(self.cl)
        draws.raptor = self.raptor
        if self.raptor:
            fl = {
                "rec": rec, "members": [], "draws": draws,
                "ptr": {}, "seq_idx": {},
                "done": {}, "running": {},
                "released": set(), "failed_members": set(),
                "n_members": 0,
                # event-driven dependency waits + deadlock detection
                "parked": set(), "done_members": set(), "pending": 0,
            }
            for m in range(max(self.wl.concurrency, 1)):
                oh = overhead if m == 0 else overhead + float(
                    self.cl.sample_overhead(self.load, 1)[0])
                self.backlog.append(("member", fl, m, oh))
            self._dispatch()
        else:
            state = {"rec": rec, "done": set(), "queued": set(),
                     "draws": draws}
            if self.fault_mode:
                # per-task attempt bookkeeping: the service draw shared by
                # the whole attempt set (deterministic re-execution — see
                # sim/policies.py), attempts committed-but-unfinished, and
                # which finalized tasks actually succeeded
                state.update(zbase={}, att_open={}, succ=set())
            self._stock_enqueue_ready(state, overhead)

    def _ready(self, done: set) -> List[str]:
        return [t for t in self._stock_tasks
                if t not in done
                and all(d in done for d in self._stock_deps[t])]

    def _stock_enqueue_ready(self, state, overhead):
        """Stage hops (control plane + storage round-trips) elapse BEFORE a
        worker is occupied — they are control-path delays, not service."""
        for task in self._ready(state["done"]):
            if task not in state["queued"]:
                state["queued"].add(task)
                if self.fault_mode:
                    state["att_open"][task] = 1
                self.q.schedule(self.q.now + overhead, self._stock_push,
                                state, task)

    def _stock_push(self, state, task, attempt: int = 0):
        self.backlog.append(("task", state["rec"], task, state, attempt))
        self._dispatch()

    # ------------------------------------------------------------------
    def _dispatch(self):
        while self.backlog and self.free:
            kind = self.backlog[0][0]
            if kind == "task":
                _, rec, task, state, att = self.backlog.pop(0)
                if self.fault_mode:
                    self._stock_dispatch_attempt(rec, state, task, att)
                    continue
                w = self.free.pop()
                svc = state["draws"].draw(task, w)
                fail = self.rng.random() < self.wl.fail_prob
                self.q.schedule(self.q.now + svc,
                                self._stock_finish, rec, state, task, w,
                                fail, svc)
            else:
                # one flight MEMBER (paper §3.3.2: the fork's recursive
                # invocations queue independently and join the stream late)
                _, fl, member_idx, overhead = self.backlog.pop(0)
                if fl["rec"].t_done >= 0:
                    continue                      # flight already finished
                w = self._pick_worker_for(fl)
                self.free.discard(w)
                self._join_member(fl, w, member_idx, overhead)

    def _pick_worker_for(self, fl) -> int:
        """HA-aware pick: prefer AZs not yet used by this flight; with
        faults active, health trumps freshness (skip browned-out AZs,
        degrading gracefully — a fully-degraded pool still places).
        Uniform within the best tier, like the vector engine's
        ``prio + 2*healthy + fresh`` placement key."""
        used_azs = {int(self.cl.az_of[w]) for w in fl["members"]}

        def tier(w: int) -> int:
            az = int(self.cl.az_of[w])
            fresh = az not in used_azs
            if not self.fault_mode:
                return int(fresh)
            healthy = not interval_active_np(
                self.q.now, self._bs[az], self._be[az])
            return 2 * int(healthy) + int(fresh)

        best = max(tier(w) for w in self.free)
        pool = [w for w in self.free if tier(w) == best]
        return pool[int(self.rng.integers(len(pool)))]

    # ------------------------------------------------------------------
    # stock OpenWhisk fork-join, fault/policy path: every attempt is its
    # own dispatch; a failed attempt requeues up to the retry budget, a
    # slow primary gets a hedged duplicate (no cancellation — first
    # success wins, losers run to completion).  Mirrors the vector
    # engine's attempt-expanded event stream (sim/vector_queue.py).
    def _stock_dispatch_attempt(self, rec, state, task, att):
        now = self.q.now
        # earliest pushed start among FREE workers; healthy AZ, then the
        # lowest index break ties (the vector body's deterministic order)
        best = None
        for w in sorted(self.free):
            az = int(self.cl.az_of[w])
            s = push_out_np(now, self._cs[w], self._ce[w])
            key = (s, interval_active_np(s, self._bs[az], self._be[az]), w)
            if best is None or key < best[0]:
                best = (key, w, az)
        _, w, az = best
        self.free.discard(w)
        z = state["zbase"].get(task)
        if z is None:
            z = state["zbase"][task] = state["draws"].draw(task, w)
        s, end, fail = attempt_outcome_np(
            now, z, float(self.rng.random()),
            self._bs[az], self._be[az], self._cs[w], self._ce[w],
            policy=self.policy, faults=self.fp,
            base_fail=self.wl.fail_prob)
        self.q.schedule(end, self._stock_attempt_finish,
                        rec, state, task, w, fail, att, now)
        # hedge commit: the primary's outcome is already determined, so
        # the "still running at start + hedge_ms" test is exact here and
        # matches the vector's ready_hedge = start0 + hedge_ms gate
        if (att == 0 and self.policy.has_hedge
                and end > s + self.policy.hedge_ms):
            state["att_open"][task] += 1
            self.q.schedule(s + self.policy.hedge_ms, self._stock_push,
                            state, task, self.policy.chain_attempts)

    def _stock_attempt_finish(self, rec, state, task, w, fail, att, t_disp):
        self.free.add(w)
        rec.work_ms += self.q.now - t_disp
        # chain continues regardless of other attempts (no cancellation);
        # the hedge slot (att == chain_attempts) never retries
        if fail and att < self.policy.max_retries:
            state["att_open"][task] += 1
            delay = self.policy.backoff(att, float(self.rng.random()))
            self.q.schedule(self.q.now + delay, self._stock_push,
                            state, task, att + 1)
        state["att_open"][task] -= 1
        if task not in state["done"]:
            if not fail:
                # first success finalizes the task (min successful finish)
                state["done"].add(task)
                state["succ"].add(task)
                self._stock_task_final(rec, state)
            elif state["att_open"][task] == 0:
                # every attempt exhausted: the task completes FAILED at its
                # last attempt's finish so the stage still progresses
                state["done"].add(task)
                rec.ok = False
                self._stock_task_final(rec, state)
        self._dispatch()

    def _stock_task_final(self, rec, state):
        oh = self.wl.stock_stage_overhead + float(
            self.cl.sample_overhead(self.load, 1)[0])
        self._stock_enqueue_ready(state, oh)
        if len(state["done"]) == len(self._stock_tasks):
            rec.t_done = self.q.now

    # ------------------------------------------------------------------
    # stock OpenWhisk fork-join
    def _stock_finish(self, rec, state, task, worker, fail, svc):
        self.free.add(worker)
        rec.work_ms += svc
        if fail:
            rec.ok = False
        state["done"].add(task)
        oh = self.wl.stock_stage_overhead + float(
            self.cl.sample_overhead(self.load, 1)[0])
        self._stock_enqueue_ready(state, oh)
        if len(state["done"]) == len(self._stock_tasks):
            rec.t_done = self.q.now
        self._dispatch()

    # ------------------------------------------------------------------
    # Raptor flight
    def _join_member(self, fl, w: int, member_idx: int, overhead: float):
        fl["members"].append(w)
        fl["seq_idx"][w] = member_idx % len(self._seqs)
        fl["ptr"][w] = 0
        fl["n_members"] += 1
        self._wake(fl, w, overhead)

    def _wake(self, fl, w, delay: float):
        """Schedule a member continuation, counted in ``fl["pending"]`` so
        deadlock detection can tell 'quiescent' from 'wake in flight'."""
        fl["pending"] += 1
        self.q.schedule(self.q.now + delay, self._member_wake, fl, w)

    def _member_wake(self, fl, w):
        fl["pending"] -= 1
        self._member_next(fl, w)

    def _check_deadlock(self, fl):
        """Fail the flight the moment no member can ever progress: every
        joined member parked on an unmet dependency or out of tasks, no
        attempt running, no wake pending, and the whole flight joined.
        (Without this, members parked on a dependency whose every attempt
        errored would wait forever and the event queue would never drain —
        the job could not even be *observed* as censored.)  Subsumes the
        old every-member-exhausted check: that is the ``parked``-empty
        special case.

        Retry-budget accounting: an "attempt" here is a whole folded
        timeout/retry chain (``_member_next``), so under an active
        ``RecoveryPolicy`` a member counts as exhausted on a task only
        after ``1 + max_retries`` tries — the flight is dead only when
        every dependency attempt is exhausted under the policy, never on
        the first full-member failure.  ``core.scheduler`` mirrors this
        in its ``dead_after`` fail-fast threshold."""
        if (fl["rec"].t_done < 0 and not fl["running"]
                and fl["pending"] == 0
                and fl["n_members"] >= max(self.wl.concurrency, 1)
                and len(fl["parked"]) + len(fl["done_members"])
                >= fl["n_members"]
                and len(fl["done"]) < self._K):
            fl["rec"].t_done = self.q.now
            fl["rec"].ok = False
            self._finish_flight(fl)

    def _exec_sequence(self, index: int) -> List[str]:
        from repro.core.dag import execution_sequence
        man = self.wl.graph.to_manifest(max(self.wl.concurrency, 1))
        return execution_sequence(man, index)

    def _member_next(self, fl, w):
        if fl["rec"].t_done >= 0 or w in fl["released"]:
            return
        seq = self._seqs[fl["seq_idx"][w]]
        ptr = fl["ptr"][w]
        while ptr < len(seq):
            task = seq[ptr]
            if task in fl["done"]:
                ptr += 1
                continue
            if all(d in fl["done"] for d in self._deps[task]):
                break
            # dependency not yet visible on the stream: park until a
            # completion broadcast re-wakes us half an RTT later.  Event-
            # driven, not polled — the old max(slat, 0.1)ms poll both
            # busy-polled and quantized sub-0.1ms stream latencies away
            # from the vector scan's exact broadcast+slat wake.
            fl["ptr"][w] = ptr
            fl["parked"].add(w)
            self._check_deadlock(fl)
            return
        fl["ptr"][w] = ptr
        if ptr >= len(seq):
            # member exhausted its sequence; the job fails once NO member
            # can make progress with tasks still incomplete (all attempts
            # of some task errored) — _check_deadlock's terminal case
            fl["done_members"].add(w)
            self._release_member(fl, w)
            self._check_deadlock(fl)
            return
        task = seq[ptr]
        svc = fl["draws"].draw(task, w)
        if self.fault_mode:
            # the whole timeout/retry/backoff chain folds into ONE event
            # (sim/policies.py): the member holds its worker and stays in
            # ``running`` for the chain's full span, so a peer's success
            # broadcast preempts the chain as a unit and a member
            # exhausts a task only after the full retry budget — the
            # deadlock/dead_after accounting below inherits the budget
            az = int(self.cl.az_of[w])
            t_end, fail = fold_chain_np(
                self.q.now, svc + self.wl.raptor_stage_overhead,
                self.rng, self._bs[az], self._be[az],
                self._cs[w], self._ce[w], policy=self.policy,
                faults=self.fp, base_fail=self.wl.fail_prob)
            eid = self.q.schedule(
                t_end, self._member_finish, fl, w, task, fail, self.q.now)
        else:
            fail = self.rng.random() < self.wl.fail_prob
            eid = self.q.schedule(
                self.q.now + svc + self.wl.raptor_stage_overhead,
                self._member_finish, fl, w, task, fail, self.q.now)
        fl["running"][w] = (task, eid, self.q.now)

    def _member_finish(self, fl, w, task, fail, t0):
        fl["running"].pop(w, None)
        fl["rec"].work_ms += self.q.now - t0
        fl["ptr"][w] += 1
        guard = task in self._guards
        if fail and not guard:
            # §3.3.4: the error event is broadcast and IGNORED by peers; the
            # member moves on.  The task stays pending for other members.
            fl["failed_members"].add(w)
            self._wake(fl, w, 0.0)
            return
        if task not in fl["done"]:
            fl["done"][task] = self.q.now
            if guard:
                # conditional mask-select: the guard's FIRST finished
                # attempt decides the branch — failure is a routing
                # outcome, not a job error.  Tasks gated on the other
                # sense are cancelled: marked complete with zero service
                # (they structurally depend on the guard, so none can be
                # mid-attempt here), and their dependents wake below.
                outcome = not fail
                for t, sense in self._guards[task]:
                    if sense != outcome and t not in fl["done"]:
                        fl["done"][t] = self.q.now
            # broadcast: preempt peers running `task` (half-RTT delivery)
            for pw, (ptask, eid, pt0) in list(fl["running"].items()):
                if ptask == task:
                    self.q.cancel(eid)
                    fl["running"].pop(pw)
                    fl["rec"].work_ms += (self.q.now + self.slat) - pt0
                    fl["ptr"][pw] += 0
                    self._wake(fl, pw, self.slat)
            # ...and wake members parked on a dependency: they re-check
            # their head-of-line task half an RTT after the broadcast
            # (re-parking if still blocked) — the vector scan's semantics
            for pw in list(fl["parked"]):
                fl["parked"].discard(pw)
                self._wake(fl, pw, self.slat)
        if len(fl["done"]) == self._K:
            fl["rec"].t_done = self.q.now
            fl["rec"].ok = True
            self._finish_flight(fl)
            return
        self._wake(fl, w, 0.0)

    def _finish_flight(self, fl):
        for pw, (ptask, eid, pt0) in list(fl["running"].items()):
            self.q.cancel(eid)
            fl["rec"].work_ms += self.q.now - pt0
            fl["running"].pop(pw)
        for pw in fl["members"]:
            self._release_member(fl, pw)

    def _release_member(self, fl, w):
        if w not in fl["released"]:
            fl["released"].add(w)
            self.free.add(w)
            self._dispatch()
