"""Paper-experiment drivers: one function per table/figure (DESIGN.md §7)."""
from __future__ import annotations

from typing import Dict

import numpy as np

from repro.core.analytics import (forkjoin_failure, raptor_failure,
                                  raptor_failure_exact, response_ratio_paper,
                                  summarize)
from repro.sim.cluster import Cluster
from repro.sim.flights import FlightSim
from repro.sim.workloads import (keygen_workload, reliability_workload,
                                 thumbnail_workload, wordcount_workload)

HA = dict(num_workers=15, num_azs=3)
LOW_AVAIL = dict(num_workers=5, num_azs=1)

# load levels as utilisation targets of the flight variant's capacity
UTIL = {"low": 0.18, "medium": 0.45, "high": 0.75}


def rate_for(wl, deployment: Dict, load: str) -> float:
    return UTIL[load] * deployment["num_workers"] / wl.work_est_ws


def run_pair(wl_fn, deployment: Dict, *, load: str = "medium",
             duration_s: float = 1800.0, seed: int = 0,
             rho: float = 0.95, rotate: bool = True) -> Dict[str, dict]:
    """Simulate a workload with and without Raptor; returns summary stats."""
    out = {}
    for raptor in (False, True):
        cl = Cluster(rho=rho, seed=seed, **deployment)
        wl = wl_fn()
        sim = FlightSim(cl, wl, raptor=raptor,
                        arrival_rate_hz=rate_for(wl, deployment, load),
                        duration_s=duration_s, load=load, seed=seed,
                        rotate=rotate)
        jobs = sim.run()
        s = summarize([j.response for j in jobs])
        s["work_mean"] = float(np.mean([j.work_ms for j in jobs]))
        s["fail_rate"] = float(np.mean([not j.ok for j in jobs]))
        out["raptor" if raptor else "stock"] = s
    out["mean_ratio"] = out["raptor"]["mean"] / out["stock"]["mean"]
    return out


def table6_overhead(n: int = 20000, seed: int = 0) -> Dict:
    """Control-plane overhead medians/p90s per (availability, load)."""
    rows = {}
    for ha, label in ((True, "three_az"), (False, "one_az")):
        cl = Cluster(seed=seed, **(HA if ha else LOW_AVAIL))
        for load in ("low", "medium", "high"):
            s = cl.sample_overhead(load, n)
            rows[f"{label}/{load}"] = {
                "median": float(np.median(s)),
                "p90": float(np.percentile(s, 90)),
            }
    return rows


def table7_keygen(seed: int = 0, duration_s: float = 1800.0) -> Dict:
    """SSH keygen on the HA deployment at moderate load (+ theory check)."""
    res = run_pair(keygen_workload, HA, load="medium", seed=seed,
                   duration_s=duration_s)
    res["theory_ratio"] = response_ratio_paper()
    return res


def fig6_scale_effect(seed: int = 0, duration_s: float = 1800.0) -> Dict:
    """Raptor benefit vs deployment scale and load (the paper's headline).

    Low-availability 1-AZ/5-worker: replicas co-located -> correlated ->
    ~no benefit.  HA 3-AZ/15-worker: independent -> ~2/3 ratio.
    """
    out = {}
    for name, dep in (("one_az_5w", LOW_AVAIL), ("three_az_15w", HA)):
        for load in ("low", "medium", "high"):
            wl0 = keygen_workload()
            hz = rate_for(wl0, dep, load)
            res = {}
            for raptor in (False, True):
                cl = Cluster(rho=0.95, seed=seed, **dep)
                sim = FlightSim(cl, keygen_workload(), raptor=raptor,
                                arrival_rate_hz=hz, duration_s=duration_s,
                                load=load, seed=seed)
                jobs = sim.run()
                res["raptor" if raptor else "stock"] = summarize(
                    [j.response for j in jobs])
            res["mean_ratio"] = res["raptor"]["mean"] / res["stock"]["mean"]
            out[f"{name}/{load}"] = res
    return out


def fig7_other_workloads(seed: int = 0, duration_s: float = 1800.0) -> Dict:
    return {
        "wordcount": run_pair(wordcount_workload, HA, seed=seed,
                              duration_s=duration_s),
        "thumbnail": run_pair(thumbnail_workload, HA, seed=seed,
                              duration_s=duration_s),
    }


def sweep_scale(trials: int = 20000, seed: int = 0) -> Dict:
    """Vectorized Monte-Carlo sweep across cluster scale (the tentpole).

    Covers the scalar drivers' Table 7/8 territory and extends it with the
    curves the scalar sim is too slow to produce: Raptor's mean-delay ratio
    as the deployment grows 1→8 AZs and flights grow 2→16 members.  All
    trials and order-statistics reductions run on-device (sim/vector.py +
    core/analytics.py); the scalar FlightSim remains the agreement oracle.
    """
    from repro.core.analytics import raptor_speedup_prediction
    from repro.sim.vector import (VectorFlightSim, exponential_vector,
                                  keygen_vector, reliability_vector)
    out: Dict[str, dict] = {}

    # Table 7: keygen on the HA deployment (open-loop limit) + theory
    sim = VectorFlightSim(keygen_vector(), num_azs=3, flight=2, seed=seed)
    out["table7_keygen"] = sim.run_pair(trials)
    out["table7_keygen"]["theory_ratio"] = response_ratio_paper()

    # Table 8: the keygen ratio across the three Table-6 overhead regimes
    for load in ("low", "medium", "high"):
        s = VectorFlightSim(keygen_vector(), num_azs=3, flight=2, load=load,
                            seed=seed)
        out[f"table8/{load}"] = s.run_pair(trials)

    # AZ sweep 1→8: a flight of 4 at rho=0.95 — replicas decorrelate as
    # they spread, the paper's "only at horizontal scale" effect
    az_curve = {}
    for num_azs in (1, 2, 3, 4, 6, 8):
        s = VectorFlightSim(exponential_vector(2, 1000.0), num_azs=num_azs,
                            flight=4, rho=0.95, seed=seed)
        az_curve[num_azs] = s.run_pair(trials)["mean_ratio"]
    out["az_sweep"] = {
        "ratio_by_azs": az_curve,
        "theory_independent": raptor_speedup_prediction(num_tasks=2,
                                                        flight=4),
    }

    # flight-size sweep 2→16 at full independence (8 AZs, exp tasks):
    # the mutually-independent-exponential prediction, order stat by
    # order stat
    fl_curve = {}
    for flight in (2, 4, 8, 16):
        s = VectorFlightSim(exponential_vector(2, 1000.0), num_azs=8,
                            flight=flight, rho=0.95, seed=seed)
        fl_curve[flight] = {
            "mean_ratio": s.run_pair(trials)["mean_ratio"],
            "theory": raptor_speedup_prediction(num_tasks=2, flight=flight),
        }
    out["flight_sweep"] = fl_curve

    # Figure 8 at vector scale: empirical flight failure vs the exact form
    rel = {}
    for n_tasks in (2, 4, 8):
        for p in (0.1, 0.2, 0.3):
            s = VectorFlightSim(reliability_vector(n_tasks, p), num_azs=3,
                                flight=n_tasks, seed=seed)
            r = s.run(trials, raptor=True)
            rel[f"n{n_tasks}/p{p}"] = {
                "raptor_fail": r.fail_rate(),
                "theory_exact": raptor_failure_exact(p, n_tasks),
            }
    out["reliability"] = rel
    return out


def fig8_reliability(seed: int = 0, n_jobs_s: float = 600.0) -> Dict:
    """Job vs task failure probability, N parallel tasks."""
    out = {}
    for n_tasks in (2, 4, 8):
        for p in (0.05, 0.1, 0.2, 0.3):
            wl = lambda: reliability_workload(n_tasks, p)
            res = run_pair(wl, HA, load="low", duration_s=n_jobs_s,
                           seed=seed)
            out[f"n{n_tasks}/p{p}"] = {
                "stock_fail": res["stock"]["fail_rate"],
                "raptor_fail": res["raptor"]["fail_rate"],
                "theory_stock": forkjoin_failure(p, n_tasks),
                "theory_raptor": raptor_failure(p, n_tasks),
                "theory_raptor_exact": raptor_failure_exact(p, n_tasks),
            }
    return out
