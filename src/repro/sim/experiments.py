"""Paper-experiment drivers: one function per table/figure (DESIGN.md §7)."""
from __future__ import annotations

from typing import Dict

import numpy as np

from repro.core.analytics import (forkjoin_failure, raptor_failure,
                                  raptor_failure_exact, response_ratio_paper,
                                  summarize)
from repro.sim.cluster import Cluster
from repro.sim.flights import FlightSim
from repro.sim.workloads import (UTIL, arrival_rate_hz, etl_workload,
                                 keygen_workload, mapreduce_workload,
                                 reliability_workload, thumbnail_workload,
                                 wordcount_workload)

HA = dict(num_workers=15, num_azs=3)
LOW_AVAIL = dict(num_workers=5, num_azs=1)


def rate_for(wl, deployment: Dict, load: str) -> float:
    return arrival_rate_hz(wl.work_est_ws, deployment["num_workers"], load)


def summarize_jobs(jobs) -> dict:
    """Delay summary conditioned on SUCCESS, failure accounting alongside.

    A failed job's "response" is the failure-*detection* time (when the
    last member gave up), not a delay a client would see — mixing those
    into ``summarize`` biases the raptor delay means/tails whenever
    ``fail_prob > 0``.  ``n`` counts the successful jobs summarized;
    ``fail_rate`` is still over ALL jobs and ``n_failed`` is reported so
    nothing is silently dropped.  The vectorized engines' ``summary()``
    follows the same convention.
    """
    ok = [j.response for j in jobs if j.ok]
    if ok:
        s = summarize(ok)
    else:
        nan = float("nan")
        s = dict(mean=nan, median=nan, p90=nan, p99=nan, scv=nan, n=0)
    s["fail_rate"] = float(np.mean([not j.ok for j in jobs])) if jobs else 0.0
    s["n_failed"] = int(sum(not j.ok for j in jobs))
    return s


def run_pair(wl_fn, deployment: Dict, *, load: str = "medium",
             duration_s: float = 1800.0, seed: int = 0,
             rho: float = 0.95, rotate: bool = True) -> Dict[str, dict]:
    """Simulate a workload with and without Raptor; returns summary stats
    (delay stats success-conditioned, see :func:`summarize_jobs`)."""
    out = {}
    for raptor in (False, True):
        cl = Cluster(rho=rho, seed=seed, **deployment)
        wl = wl_fn()
        sim = FlightSim(cl, wl, raptor=raptor,
                        arrival_rate_hz=rate_for(wl, deployment, load),
                        duration_s=duration_s, load=load, seed=seed,
                        rotate=rotate)
        jobs = sim.run()
        s = summarize_jobs(jobs)
        s["work_mean"] = float(np.mean([j.work_ms for j in jobs]))
        out["raptor" if raptor else "stock"] = s
    out["mean_ratio"] = out["raptor"]["mean"] / out["stock"]["mean"]
    return out


def table6_overhead(n: int = 20000, seed: int = 0) -> Dict:
    """Control-plane overhead medians/p90s per (availability, load)."""
    rows = {}
    for ha, label in ((True, "three_az"), (False, "one_az")):
        cl = Cluster(seed=seed, **(HA if ha else LOW_AVAIL))
        for load in ("low", "medium", "high"):
            s = cl.sample_overhead(load, n)
            rows[f"{label}/{load}"] = {
                "median": float(np.median(s)),
                "p90": float(np.percentile(s, 90)),
            }
    return rows


def table7_keygen(seed: int = 0, duration_s: float = 1800.0) -> Dict:
    """SSH keygen on the HA deployment at moderate load (+ theory check)."""
    res = run_pair(keygen_workload, HA, load="medium", seed=seed,
                   duration_s=duration_s)
    res["theory_ratio"] = response_ratio_paper()
    return res


def fig6_scale_effect(seed: int = 0, duration_s: float = 1800.0,
                      engine: str = "vector", jobs: int = None,
                      trials: int = 32) -> Dict:
    """Raptor benefit vs deployment scale and load (the paper's headline).

    Low-availability 1-AZ/5-worker: replicas co-located -> correlated ->
    ~no benefit.  HA 3-AZ/15-worker: independent -> ~2/3 ratio.

    ``engine="vector"`` (default) replays the closed-loop batched queue
    engine (sim/vector_queue.py): both deployments x three loads in two
    compilations, minutes -> sub-second warm.  One vector *trial* is one
    ``duration_s``-long arrival stream (``jobs`` overrides the derived
    per-trial stream length), so the scalar knob keeps meaning.
    ``engine="scalar"`` runs the event-driven oracle the vector engine is
    validated against (tests/test_sim_queue.py).
    """
    out = {}
    if engine == "vector":
        try:
            from repro.sim.vector_queue import keygen_queue, load_sweep
        except ImportError:       # numpy-only interpreter: scalar oracle
            engine = "scalar"
    if engine == "vector":
        for name, dep in (("one_az_5w", LOW_AVAIL), ("three_az_15w", HA)):
            n = jobs if jobs is not None else max(256, int(
                rate_for(keygen_workload(), dep, "medium") * duration_s))
            res = load_sweep(keygen_queue(), num_workers=dep["num_workers"],
                             num_azs=dep["num_azs"], jobs=n,
                             trials=trials, seed=seed)
            for load, pair in res.items():
                out[f"{name}/{load}"] = pair
        return out
    for name, dep in (("one_az_5w", LOW_AVAIL), ("three_az_15w", HA)):
        for load in ("low", "medium", "high"):
            wl0 = keygen_workload()
            hz = rate_for(wl0, dep, load)
            res = {}
            for raptor in (False, True):
                cl = Cluster(rho=0.95, seed=seed, **dep)
                sim = FlightSim(cl, keygen_workload(), raptor=raptor,
                                arrival_rate_hz=hz, duration_s=duration_s,
                                load=load, seed=seed)
                res["raptor" if raptor else "stock"] = summarize_jobs(
                    sim.run())
            res["mean_ratio"] = res["raptor"]["mean"] / res["stock"]["mean"]
            out[f"{name}/{load}"] = res
    return out


def fig7_other_workloads(seed: int = 0, duration_s: float = 1800.0,
                         engine: str = "vector", jobs: int = None,
                         trials: int = 16, load: str = "medium") -> Dict:
    """Wordcount + thumbnail DAG manifests (paper fig 7), HA deployment.

    The vector engine replays the DAG dependency masks on-device (one
    trial = one ``duration_s``-long arrival stream unless ``jobs`` is
    given); the scalar path is the agreement oracle (same semantics,
    ~10-50x slower).  ``load`` selects the utilisation/overhead regime —
    ``"high"`` (util 0.75) is now faithful on the stock side too, since
    the vector stock path replays at task granularity (task-level FCFS,
    the scalar oracle's discipline; tests/test_sim_queue.py).
    """
    if engine == "vector":
        try:
            from repro.sim.vector_queue import (QueueFlightSim,
                                                thumbnail_queue,
                                                wordcount_queue)
        except ImportError:       # numpy-only interpreter: scalar oracle
            return fig7_other_workloads(seed=seed, duration_s=duration_s,
                                        engine="scalar", load=load)
        out = {}
        for name, qwl in (("wordcount", wordcount_queue()),
                          ("thumbnail", thumbnail_queue())):
            sim = QueueFlightSim(qwl, load=load, seed=seed, **HA)
            n = jobs if jobs is not None else max(
                256, int(sim.rate_hz * duration_s))
            out[name] = sim.run_pair(n, trials)
        return out
    return {
        "wordcount": run_pair(wordcount_workload, HA, seed=seed,
                              duration_s=duration_s, load=load),
        "thumbnail": run_pair(thumbnail_workload, HA, seed=seed,
                              duration_s=duration_s, load=load),
    }


def workflow_bank(seed: int = 0, duration_s: float = 600.0,
                  engine: str = "vector", jobs: int = None,
                  trials: int = 8, load: str = "medium",
                  streaming: bool = True) -> Dict:
    """The spec-compiled workload bank end to end (EXPERIMENTS.md
    §manifests): the multi-stage ETL pipeline (conditional poison-job
    quarantine behind the ``validate`` guard) and the ranked map-reduce
    with a sync barrier, each compiled by :mod:`repro.core.workflow` and
    replayed through every engine.

    ``engine="vector"`` (default) runs the closed-loop batched queue
    engine and — when ``streaming=True`` — the open-arrival streaming
    scheduler with its block=1 oracle identity check; ``"scalar"`` runs
    the event-driven oracle (same compiled graphs, agreement pinned in
    tests/test_workflow.py).  Each row carries the graph's
    ``manifest_hash`` — the compiled-content identity bench records and
    sweep bucket keys share.
    """
    banks = (("etl", etl_workload, None),
             ("mapreduce", mapreduce_workload, None))
    if engine == "scalar":
        out = {}
        for name, wl_fn, _ in banks:
            res = run_pair(wl_fn, HA, seed=seed, duration_s=duration_s,
                           load=load)
            res["manifest_hash"] = wl_fn().graph.manifest_hash
            out[name] = res
        return out
    from repro.sim.streaming import oracle_check, run_open_load
    from repro.sim.vector_queue import (QueueFlightSim, etl_queue,
                                        mapreduce_queue)
    out = {}
    for name, _, __ in banks:
        qwl = etl_queue() if name == "etl" else mapreduce_queue()
        sim = QueueFlightSim(qwl, load=load, seed=seed, **HA)
        n = jobs if jobs is not None else max(
            256, int(sim.rate_hz * duration_s))
        res = sim.run_pair(n, trials)
        res["manifest_hash"] = qwl.graph.manifest_hash
        if streaming:
            rep = run_open_load(sim, jobs=min(n, 1024), microbatch=64,
                                seed=seed)
            res["streaming"] = {
                "jobs_per_s": rep.jobs_per_s, "mean_ms": rep.mean_ms,
                "p99_ms": rep.p99_ms, "ok_frac": rep.ok_frac,
            }
            res["streaming_bitwise_oracle"] = oracle_check(
                sim, n_steps=3, microbatch=32)["bitwise"]
        out[name] = res
    return out


def load_sweep_util(utils=(0.15, 0.3, 0.45, 0.6, 0.75, 0.9), seed: int = 0,
                    jobs: int = 1024, trials: int = 16,
                    devices=None) -> Dict:
    """Closed-loop keygen ratio across a *continuous* utilisation grid.

    A thin plan over the device-sharded sweep driver (sim/sweeps.py): the
    arrival rate is a traced argument of the queue engine, so the whole
    grid per deployment is one compilation with the utilisation axis
    sharded over ``devices`` (default: every jax device) — the fig6 curve
    at arbitrary resolution (a regime the scalar sim cannot sweep in
    reasonable time).  Overheads use the Table-6 regime nearest each
    utilisation.  The 0.9 point probes deep into the queueing regime the
    task-FCFS stock engine made faithful; note the 1-AZ/5-worker
    deployment is saturated by the flights there (raptor util > 1) — its
    window-length-dependent numbers are only comparable as backlog growth
    rates (tests/test_sim_queue.py's saturation test), not as steady-state
    means.
    """
    from repro.sim.vector_queue import keygen_queue, rate_sweep
    out: Dict[str, dict] = {}
    for name, dep in (("one_az_5w", LOW_AVAIL), ("three_az_15w", HA)):
        wl = keygen_queue()
        rates = [u * dep["num_workers"] / wl.work_est_ws for u in utils]
        loads = ["low" if u < 0.3 else ("medium" if u < 0.6 else "high")
                 for u in utils]
        res = rate_sweep(wl, rates, loads=loads,
                         num_workers=dep["num_workers"],
                         num_azs=dep["num_azs"], jobs=jobs, trials=trials,
                         seed=seed, devices=devices)
        for u, pair in zip(utils, res):
            out[f"{name}/util{u:.2f}"] = pair
    return out


def sweep_scale(trials: int = 20000, seed: int = 0, devices=None) -> Dict:
    """Vectorized Monte-Carlo sweep across cluster scale (the tentpole).

    Covers the scalar drivers' Table 7/8 territory and extends it with the
    curves the scalar sim is too slow to produce: Raptor's mean-delay ratio
    as the deployment grows 1→8 AZs and flights grow 2→16 members.  All
    trials and order-statistics reductions run on-device (sim/vector.py +
    core/analytics.py); the scalar FlightSim remains the agreement oracle.
    The AZ/flight grid goes through the device-sharded sweep driver
    (``devices`` as in :func:`repro.sim.vector.sweep_pairs`; sharded runs
    are bit-identical to single-device ones, tests/test_sweeps.py).
    """
    from repro.core.analytics import (raptor_plateau_prediction,
                                      raptor_speedup_prediction)
    from repro.sim.vector import (VectorFlightSim, exponential_vector,
                                  keygen_vector, reliability_vector,
                                  sweep_pairs)
    out: Dict[str, dict] = {}

    # Table 7: keygen on the HA deployment (open-loop limit) + theory
    sim = VectorFlightSim(keygen_vector(), num_azs=3, flight=2, seed=seed)
    out["table7_keygen"] = sim.run_pair(trials)
    out["table7_keygen"]["theory_ratio"] = response_ratio_paper()

    # Table 8: the keygen ratio across the three Table-6 overhead regimes
    for load in ("low", "medium", "high"):
        s = VectorFlightSim(keygen_vector(), num_azs=3, flight=2, load=load,
                            seed=seed)
        out[f"table8/{load}"] = s.run_pair(trials)

    # AZ sweep 1→8 (flight of 4) and flight sweep 2→16 (8 AZs): the whole
    # grid runs pad-and-masked through sweep_pairs — flight size and AZ
    # count are traced, so the curves share a handful of compilations
    # instead of paying one (~1.5s, BENCH_sim.json) per point
    az_points = [dict(flight=4, num_azs=a) for a in (1, 2, 3, 4, 6, 8)]
    fl_points = [dict(flight=f, num_azs=8) for f in (2, 4, 8, 16)]
    wl = exponential_vector(2, 1000.0)
    res = sweep_pairs(wl, az_points + fl_points, trials=trials, seed=seed,
                      devices=devices)
    az_res, fl_res = res[:len(az_points)], res[len(az_points):]
    out["az_sweep"] = {
        "ratio_by_azs": {c["num_azs"]: r["mean_ratio"]
                         for c, r in zip(az_points, az_res)},
        "theory_independent": raptor_speedup_prediction(num_tasks=2,
                                                        flight=4),
    }
    out["flight_sweep"] = {
        c["flight"]: {
            "mean_ratio": r["mean_ratio"],
            "theory": raptor_speedup_prediction(num_tasks=2,
                                                flight=c["flight"]),
            "theory_corrected": raptor_plateau_prediction(
                num_tasks=2, flight=c["flight"]),
        } for c, r in zip(fl_points, fl_res)}

    # paper-gap probe (EXPERIMENTS.md): at F >> K the measured ratio
    # plateaus far above the K*E[min_F]/E[max_K] prediction and onto the
    # corrected K*E[min_{F/K}]/E[max_K] form (effective race width F/K).
    # Randomised (non-cyclic) member orders barely move it — the plateau
    # is the split of the flight over the tasks (only ~F/K members race
    # any one task), not an artefact of cyclic-shift duplication.
    rnd = VectorFlightSim(exponential_vector(2, 1000.0), num_azs=8,
                          flight=16, rho=0.95, seed=seed,
                          sequences="random")
    out["flight_sweep_random"] = {
        "flight": 16,
        "mean_ratio": rnd.run_pair(trials)["mean_ratio"],
        "cyclic_ratio": out["flight_sweep"][16]["mean_ratio"],
        "theory": raptor_speedup_prediction(num_tasks=2, flight=16),
        "theory_corrected": raptor_plateau_prediction(num_tasks=2,
                                                      flight=16),
    }

    # Figure 8 at vector scale: empirical flight failure vs the exact form
    rel = {}
    for n_tasks in (2, 4, 8):
        for p in (0.1, 0.2, 0.3):
            s = VectorFlightSim(reliability_vector(n_tasks, p), num_azs=3,
                                flight=n_tasks, seed=seed)
            r = s.run(trials, raptor=True)
            rel[f"n{n_tasks}/p{p}"] = {
                "raptor_fail": r.fail_rate(),
                "theory_exact": raptor_failure_exact(p, n_tasks),
            }
    out["reliability"] = rel
    return out


def fig8_reliability(seed: int = 0, n_jobs_s: float = 600.0) -> Dict:
    """Job vs task failure probability, N parallel tasks."""
    out = {}
    for n_tasks in (2, 4, 8):
        for p in (0.05, 0.1, 0.2, 0.3):
            wl = lambda: reliability_workload(n_tasks, p)
            res = run_pair(wl, HA, load="low", duration_s=n_jobs_s,
                           seed=seed)
            out[f"n{n_tasks}/p{p}"] = {
                "stock_fail": res["stock"]["fail_rate"],
                "raptor_fail": res["raptor"]["fail_rate"],
                "theory_stock": forkjoin_failure(p, n_tasks),
                "theory_raptor": raptor_failure(p, n_tasks),
                "theory_raptor_exact": raptor_failure_exact(p, n_tasks),
            }
    return out


def fault_sweep(seed: int = 0, trials: int = 40_000,
                mc_samples: int = 20_000) -> Dict:
    """Independence-prediction hold vs break under AZ brownouts (§faults).

    The §4.2.1 speedup predictions assume mutually independent member
    executions.  This sweep injects the same stationary brownout mixture
    twice — per-AZ i.i.d. processes vs ONE shared (correlated) process —
    and holds the independence-assuming mixture prediction
    (:func:`repro.core.analytics.mixture_speedup_prediction`) against the
    measured open-loop mean ratio:

    * **i.i.d. brownouts**: degradation indicators stay independent
      across members, so the prediction tracks the measured ratio — the
      paper's predictability claim survives a degraded-but-uncorrelated
      cluster;
    * **correlated brownouts**: the whole flight inflates together, the
      min-race stops hedging the slow state, and the measured ratio pulls
      away from the (unchanged) independence prediction — the regime
      where the claim breaks.

    A closed-loop row repeats the comparison with queueing (keygen on the
    HA deployment) where correlation additionally feeds back through the
    backlog, and a recovery-policy row shows timeout+retry clawing back
    part of the correlated-tail damage.  Recorded in EXPERIMENTS.md
    §faults.
    """
    from repro.core.analytics import mixture_speedup_prediction
    from repro.sim.faults import FaultProfile
    from repro.sim.policies import RecoveryPolicy
    from repro.sim.vector import VectorFlightSim, exponential_vector
    from repro.sim.vector_queue import QueueFlightSim, keygen_queue

    mean_ms, K, F = 1000.0, 2, 2
    base = dict(az_mtbf_ms=24_000.0, az_mttr_ms=6_000.0,
                degraded_inflation=3.0)
    pi = FaultProfile(**base).stationary_degraded
    out: Dict[str, dict] = {"profile": dict(base, stationary_degraded=pi)}

    # open-loop: prediction vs measured, both brownout regimes
    pred = mixture_speedup_prediction(
        K, F, p_deg=pi, inflation=base["degraded_inflation"],
        n_samples=mc_samples, seed=seed)
    for tag, corr in (("iid", False), ("correlated", True)):
        fp = FaultProfile(correlated=corr, **base)
        wl = exponential_vector(K, mean_ms, faults=fp)
        pair = VectorFlightSim(wl, num_azs=3, flight=F, load="low",
                               seed=seed).run_pair(trials)
        out[f"open_loop/{tag}"] = {
            "measured_ratio": pair["mean_ratio"],
            "predicted_ratio": pred,
            "rel_err": abs(pair["mean_ratio"] - pred) / pred,
            "raptor": pair["raptor"], "stock": pair["stock"],
        }

    # closed-loop keygen: correlation also feeds the backlog; a recovery
    # policy (timeout + retry) trims the correlated tail
    pol = RecoveryPolicy(timeout_ms=6_000.0, max_retries=1,
                         backoff_ms=50.0)
    for tag, corr in (("iid", False), ("correlated", True)):
        fp = FaultProfile(correlated=corr, **base)
        sim = QueueFlightSim(keygen_queue(faults=fp), load="medium",
                             seed=seed)
        out[f"closed_loop/{tag}"] = sim.run_pair(jobs=1024, trials=16)
        simp = QueueFlightSim(keygen_queue(faults=fp, recovery=pol),
                              load="medium", seed=seed)
        out[f"closed_loop_policy/{tag}"] = simp.run_pair(jobs=1024,
                                                         trials=16)
    return out
