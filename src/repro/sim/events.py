"""Minimal cancellable discrete-event engine for the cluster simulator."""
from __future__ import annotations

import heapq
import itertools
from typing import Any, Callable, Dict, Optional


class EventQueue:
    def __init__(self):
        self._pq = []
        self._counter = itertools.count()
        self._cancelled = set()
        self.now = 0.0

    def schedule(self, t: float, fn: Callable, *args) -> int:
        eid = next(self._counter)
        heapq.heappush(self._pq, (t, eid, fn, args))
        return eid

    def cancel(self, eid: int):
        self._cancelled.add(eid)

    def run(self, until: float = float("inf")):
        while self._pq:
            t, eid, fn, args = heapq.heappop(self._pq)
            if eid in self._cancelled:
                self._cancelled.discard(eid)
                continue
            if t > until:
                self.now = until
                return
            self.now = t
            fn(*args)
