"""Event infrastructure: the scalar sim's cancellable discrete-event
engine, plus the open-arrival processes of the streaming traffic bank.

The arrival processes are host-side numpy generators (the streaming
scheduler ingests the next microbatch on the host while the device books
the previous one, so arrivals never need to be jax-traced).  All three
share one contract: ``take(n)`` returns the next ``n`` absolute arrival
times in milliseconds, strictly continuing from the previous call —
concatenating the batches reproduces the single infinite stream, which is
what makes N microbatched scheduler steps bitwise-comparable to one
whole-trace replay of the concatenated stream (tests/test_streaming.py).
"""
from __future__ import annotations

import heapq
import itertools
import math
from typing import Any, Callable, Dict, Optional

import numpy as np


class ArrivalProcess:
    """Base class: a resumable stream of absolute arrival times (ms).

    Subclasses implement ``_gaps(n)`` -> n inter-arrival gaps in ms;
    ``take`` accumulates them onto the running clock.
    """

    def __init__(self, rate_hz: float, seed: int = 0):
        if not (rate_hz > 0.0 and math.isfinite(rate_hz)):
            raise ValueError(
                f"rate_hz must be a positive finite rate, got {rate_hz}")
        self.rate_hz = float(rate_hz)
        self.seed = int(seed)
        self.reset()

    def reset(self) -> None:
        """Rewind to t=0 with the seeded generator state."""
        self._rng = np.random.default_rng(self.seed)
        self._now_ms = 0.0
        self._reset_state()

    def _reset_state(self) -> None:   # subclass hook
        pass

    def _gaps(self, n: int) -> np.ndarray:
        raise NotImplementedError

    def take(self, n: int) -> np.ndarray:
        """Next ``n`` absolute arrival times (ms), float64, sorted."""
        if n < 0:
            raise ValueError(f"take(n) needs n >= 0, got {n}")
        if n == 0:
            return np.empty(0, dtype=np.float64)
        t = self._now_ms + np.cumsum(self._gaps(int(n)))
        self._now_ms = float(t[-1])
        return t


class PoissonArrivals(ArrivalProcess):
    """Homogeneous Poisson arrivals at ``rate_hz`` — the baseline the
    whole-trace replay draws (exponential gaps, mean 1000/rate_hz ms)."""

    def _gaps(self, n: int) -> np.ndarray:
        return self._rng.exponential(1000.0 / self.rate_hz, n)


class MMPPArrivals(ArrivalProcess):
    """2-state Markov-modulated Poisson process (bursty arrivals).

    The modulating chain alternates between a quiet and a burst state with
    exponential dwell times ``dwell_s = (quiet_s, burst_s)``; the arrival
    rate is ``rate_hz``-mean-preserving: the burst state runs at
    ``burst_factor`` times the quiet state, and the two are scaled so the
    time-average rate equals ``rate_hz`` exactly.  ``burst_factor == 1``
    degenerates to :class:`PoissonArrivals` (different gap stream — the
    dwell clock consumes draws — but the same law).

    Generation is the exact competing-exponentials method: in state ``s``
    draw an exp gap at rate ``r_s``; if it lands past the state's
    remaining dwell, advance to the dwell boundary, flip the state, and
    redraw (memorylessness makes the discard exact).
    """

    def __init__(self, rate_hz: float, burst_factor: float = 5.0,
                 dwell_s=(20.0, 4.0), seed: int = 0):
        if burst_factor < 1.0:
            raise ValueError(
                f"burst_factor must be >= 1, got {burst_factor}")
        dwell = tuple(float(d) for d in dwell_s)
        if len(dwell) != 2 or any(d <= 0.0 for d in dwell):
            raise ValueError(
                f"dwell_s must be two positive dwell means, got {dwell_s}")
        self.burst_factor = float(burst_factor)
        self.dwell_ms = (dwell[0] * 1000.0, dwell[1] * 1000.0)
        super().__init__(rate_hz, seed)
        # mean-preserving state rates: p_quiet*r_q + p_burst*r_q*bf = rate
        p_burst = self.dwell_ms[1] / (self.dwell_ms[0] + self.dwell_ms[1])
        r_quiet = self.rate_hz / (1.0 - p_burst + p_burst * self.burst_factor)
        self.state_rates_hz = (r_quiet, r_quiet * self.burst_factor)

    def _reset_state(self) -> None:
        self._state = 0
        self._dwell_left_ms = None    # lazily drawn (needs dwell_ms set)

    def _gaps(self, n: int) -> np.ndarray:
        if self._dwell_left_ms is None:
            self._dwell_left_ms = self._rng.exponential(self.dwell_ms[0])
        out = np.empty(n, dtype=np.float64)
        carry = 0.0                   # time burned crossing state boundaries
        for i in range(n):
            while True:
                gap = self._rng.exponential(
                    1000.0 / self.state_rates_hz[self._state])
                if gap < self._dwell_left_ms:
                    self._dwell_left_ms -= gap
                    out[i] = carry + gap
                    carry = 0.0
                    break
                carry += self._dwell_left_ms
                self._state = 1 - self._state
                self._dwell_left_ms = self._rng.exponential(
                    self.dwell_ms[self._state])
        return out


class DiurnalArrivals(ArrivalProcess):
    """Nonhomogeneous Poisson with a sinusoidal rate cycle.

    ``rate(t) = rate_hz * (1 + amplitude * sin(2*pi*t/period_s))`` —
    time-average rate is exactly ``rate_hz``.  Generated by Lewis-Shedler
    thinning against the peak rate ``rate_hz * (1 + amplitude)``, which is
    exact for any bounded rate function.
    """

    def __init__(self, rate_hz: float, amplitude: float = 0.6,
                 period_s: float = 60.0, seed: int = 0):
        if not 0.0 <= amplitude < 1.0:
            raise ValueError(
                f"amplitude must be in [0, 1) so the rate stays positive, "
                f"got {amplitude}")
        if period_s <= 0.0:
            raise ValueError(f"period_s must be positive, got {period_s}")
        self.amplitude = float(amplitude)
        self.period_ms = float(period_s) * 1000.0
        super().__init__(rate_hz, seed)

    def rate_at_ms(self, t_ms) -> np.ndarray:
        return self.rate_hz * (1.0 + self.amplitude
                               * np.sin(2.0 * np.pi * t_ms / self.period_ms))

    def _gaps(self, n: int) -> np.ndarray:
        peak = self.rate_hz * (1.0 + self.amplitude)
        offsets = np.empty(n, dtype=np.float64)
        t = 0.0                       # offset past the last take() boundary
        for i in range(n):
            while True:
                t += self._rng.exponential(1000.0 / peak)
                lam = self.rate_at_ms(self._now_ms + t)
                if self._rng.uniform() * peak <= lam:
                    offsets[i] = t
                    break
        return np.diff(offsets, prepend=0.0)


class EventQueue:
    def __init__(self):
        self._pq = []
        self._counter = itertools.count()
        self._cancelled = set()
        self.now = 0.0

    def schedule(self, t: float, fn: Callable, *args) -> int:
        eid = next(self._counter)
        heapq.heappush(self._pq, (t, eid, fn, args))
        return eid

    def cancel(self, eid: int):
        self._cancelled.add(eid)

    def run(self, until: float = float("inf")):
        while self._pq:
            t, eid, fn, args = heapq.heappop(self._pq)
            if eid in self._cancelled:
                self._cancelled.discard(eid)
                continue
            if t > until:
                self.now = until
                return
            self.now = t
            fn(*args)
