"""Streaming Raptor scheduler: open arrivals on a persistent W-state.

Everything before this module is whole-trace replay of a pre-drawn event
stream.  Here the blocked event-replay core (:mod:`repro.sim.scan_core`)
runs as a *continuously loaded service*: jobs arrive from an open
:class:`repro.sim.events.ArrivalProcess`, the host microbatches them,
draws their event tensors, and books each microbatch against a
**persistent, device-resident per-worker free-at vector** — the only
state that survives between steps.  The step is jitted with the W-buffer
donated, and harvesting is deferred behind a small pipeline depth so host
ingest/draw of microbatch ``k+1`` overlaps device booking of microbatch
``k`` (JAX async dispatch; ``jax.block_until_ready`` only on harvest —
the double-buffering the ROADMAP item asks for).

Exactness: each microbatch is replayed by the SAME booking body the
whole-trace engine uses (:func:`repro.sim.vector_queue._raptor_stream_fns`
shares the draw + body helpers with ``_raptor_trial_fn``).  A job
observes earlier jobs only through the carried W-vector, so N
consecutive steps over slices of a stream compose to exactly one replay
of the concatenated stream — and every (block, resolver, scan) config of
the substrate is already pinned bitwise against the block=1 sequential
oracle.  :func:`oracle_check` exercises the composition end-to-end: it
replays the concatenated event tensors the engine actually booked
through one whole-trace :func:`repro.sim.scan_core.blocked_event_replay`
and compares runs AND traces bitwise (tests/test_streaming.py pins this
with faults on and off).

Padding: jit wants one shape, so the final partial microbatch is padded
with ``inf`` arrivals — the substrate's dead-event convention (releases
gated to ``-inf``) books nothing for them, leaving the W-state bitwise
untouched; padded outputs are masked out at harvest.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.sim.cluster import lognormal_params
from repro.sim.events import ArrivalProcess, PoissonArrivals
from repro.sim.vector_queue import QueueFlightSim, _raptor_stream_fns


@dataclasses.dataclass
class StreamingReport:
    """Sustained-load summary of one open-arrival run."""
    jobs: int                    # live (non-padded) jobs booked
    ok_frac: float               # fraction that completed successfully
    wall_s: float                # host wall-clock of the submit+drain loop
    jobs_per_s: float            # sustained throughput (jobs / wall_s)
    mean_ms: float               # mean sojourn (arrival -> response), ok only
    p50_ms: float
    p99_ms: float
    slo_ms: float
    slo_violation_frac: float    # P(sojourn > slo_ms or failed)
    horizon_ms: float            # sim-time of the last arrival
    offered_rate_hz: float       # jobs / horizon (the realized arrival rate)

    def summary(self) -> dict:
        return dataclasses.asdict(self)


class StreamingScheduler:
    """Continuously running Raptor scheduling engine.

    ``sim`` supplies the deployment (workers/AZs/flight), workload, fault
    environment, and blocked-substrate config exactly as for whole-trace
    runs; the scheduler only changes *when* events are booked, never how.

    Lifecycle::

        eng = StreamingScheduler(sim, microbatch=64)
        for batch_ms in ...:          # host arrival ingest
            eng.submit(batch_ms)      # async: device books, host returns
        resp_ms, ok = eng.drain()     # block + harvest everything

    ``pipeline_depth`` bounds how many in-flight microbatches may sit
    undispatched-on-host/unharvested before ``submit`` blocks on the
    oldest; 2 = classic double buffering.  ``keep_events=True`` records
    the drawn event tensors (+ the one-shot fault env) so
    :func:`oracle_check` can replay the identical stream whole-trace.
    """

    def __init__(self, sim: QueueFlightSim, *, microbatch: int = 64,
                 pipeline_depth: int = 2, trace: bool = False,
                 keep_events: bool = False, seed: Optional[int] = None):
        if microbatch < 1:
            raise ValueError(f"microbatch must be >= 1, got {microbatch}")
        if pipeline_depth < 1:
            raise ValueError(
                f"pipeline_depth must be >= 1, got {pipeline_depth}")
        self.sim = sim
        self.microbatch = int(microbatch)
        self.pipeline_depth = int(pipeline_depth)
        self.trace = bool(trace)
        self.keep_events = bool(keep_events)
        blk, res, sc = sim.engine_config("raptor")
        self.config = (blk, res, sc)
        self._fns = _raptor_stream_fns(
            sim.W, sim.A, sim.flight, sim.wl.graph,
            sim.wl.dist, sim.wl.fail_prob, sim._fp, sim._policy,
            blk, res, sc, sim.summary_backend, trace)
        # draw_events/step arrive pre-jitted from the lru-cached factory
        # (one compiled executable per static config, W-buffer donated)
        draw_env, self._draw, self._step = self._fns
        base = jax.random.PRNGKey(sim.seed if seed is None else int(seed))
        k_env, self._k_stream = jax.random.split(base)
        # fault tables are exogenous wall-clock interval processes, drawn
        # ONCE per stream — exactly the whole-trace replay's per-trial draw
        self.env = draw_env(k_env)
        self.wf = jnp.zeros(sim.W)
        self._steps = 0
        self._pending = collections.deque()   # (outs, live, arrivals_ms)
        self._done = []
        self._events = [] if keep_events else None
        self.jobs_submitted = 0

    # -- ingest --------------------------------------------------------
    def submit(self, arrivals_ms) -> None:
        """Book one microbatch of absolute arrival times (ms, sorted).

        Returns as soon as the device work is dispatched; blocks only when
        the pipeline is ``pipeline_depth`` deep (harvesting the oldest).
        Arrivals must not precede the previous microbatch (the W-state
        carries the past; booking cannot rewind it).
        """
        arr = np.asarray(arrivals_ms, dtype=np.float64)
        if arr.ndim != 1 or arr.size == 0:
            raise ValueError("submit wants a non-empty 1-D array of "
                             f"arrival times, got shape {arr.shape}")
        if arr.size > self.microbatch:
            raise ValueError(f"microbatch holds {self.microbatch} jobs, "
                             f"got {arr.size}")
        if np.any(np.diff(arr) < 0.0):
            raise ValueError("arrivals within a microbatch must be sorted")
        live = np.zeros(self.microbatch, dtype=bool)
        live[:arr.size] = True
        padded = np.full(self.microbatch, np.inf)
        padded[:arr.size] = arr
        sim = self.sim
        key = jax.random.fold_in(self._k_stream, self._steps)
        wl = sim.wl
        events = self._draw(
            key, jnp.asarray(padded, dtype=jnp.float32), sim.rho,
            jnp.asarray(wl.task_means, dtype=jnp.float32), wl.offset_ms,
            wl.cv, wl.raptor_stage_ms, sim.oh_mu, sim.oh_sigma)
        if self._events is not None:
            self._events.append(events)
        self.wf, outs = self._step(self.wf, events, self.env, sim.slat)
        self._pending.append((outs, live, padded))
        self._steps += 1
        self.jobs_submitted += int(arr.size)
        while len(self._pending) > self.pipeline_depth:
            self._harvest_one()

    def _harvest_one(self) -> None:
        outs, live, arr = self._pending.popleft()
        outs = jax.block_until_ready(outs)
        self._done.append((outs, live, arr))

    # -- harvest -------------------------------------------------------
    def drain(self):
        """Block on everything in flight; return ``(resp_ms, ok)`` host
        arrays over all live jobs submitted so far (padding dropped)."""
        while self._pending:
            self._harvest_one()
        jax.block_until_ready(self.wf)
        if not self._done:
            return np.empty(0, np.float32), np.empty(0, bool)
        resp = np.concatenate(
            [np.asarray(o[0])[live] for o, live, _ in self._done])
        ok = np.concatenate(
            [np.asarray(o[1])[live] for o, live, _ in self._done])
        return resp, ok

    def drain_trace(self):
        """Like :meth:`drain` but with the per-member booking trace:
        ``(resp, ok, arrival, dispatch, worker, release)`` (live jobs)."""
        if not self.trace:
            raise ValueError("construct with trace=True to record traces")
        while self._pending:
            self._harvest_one()
        jax.block_until_ready(self.wf)
        cols = [np.concatenate([np.asarray(o[i])[live]
                                for o, live, _ in self._done])
                for i in range(5)]
        arr = np.concatenate([a[live] for _, live, a in self._done])
        resp, ok, disp, widx, rel = cols
        return resp, ok, arr, disp, widx, rel

    def concatenated_events(self):
        """The full drawn event stream (requires ``keep_events=True``) —
        the exact tensors every microbatch booked, padding included."""
        if self._events is None:
            raise ValueError("construct with keep_events=True")
        return jax.tree_util.tree_map(
            lambda *xs: jnp.concatenate(xs), *self._events)


def oracle_check(sim: QueueFlightSim, *, n_steps: int = 6,
                 microbatch: int = 32, process: ArrivalProcess = None,
                 ragged_tail: bool = True, trace: bool = False,
                 seed: Optional[int] = None) -> dict:
    """Replay the streaming engine's event stream whole-trace and compare.

    Runs ``n_steps`` microbatches through :class:`StreamingScheduler`
    (recording the drawn event tensors), then books the concatenated
    stream in ONE :func:`blocked_event_replay` call via the block=1
    sequential oracle with the same fault env and a zero W-state — the
    composition the module docstring argues is exact.  Returns bitwise
    equality per output column (runs, and traces when ``trace=True``).
    """
    if process is None:
        process = PoissonArrivals(sim.rate_hz, seed=sim.seed + 17)
    eng = StreamingScheduler(sim, microbatch=microbatch, trace=trace,
                             keep_events=True, seed=seed)
    for i in range(n_steps):
        n = microbatch
        if ragged_tail and i == n_steps - 1:
            n = max(1, microbatch // 3)     # exercise the padded tail
        eng.submit(process.take(n))
    streamed = (eng.drain_trace() if trace else eng.drain())
    events = eng.concatenated_events()
    _, _, oracle_step = _raptor_stream_fns(
        sim.W, sim.A, sim.flight, sim.wl.graph,
        sim.wl.dist, sim.wl.fail_prob, sim._fp, sim._policy,
        1, "fixpoint", "seq", sim.summary_backend, trace)
    _, outs = oracle_step(jnp.zeros(sim.W), events, eng.env, sim.slat)
    live = np.isfinite(
        np.asarray(jax.tree_util.tree_leaves(events)[0], dtype=np.float64))
    names = (("resp", "ok", "arrival", "dispatch", "worker", "release")
             if trace else ("resp", "ok"))
    oracle_cols = list(outs)
    if trace:
        # streamed drain_trace interleaves the submitted arrivals; the
        # oracle stream's live arrivals are the same tensor positions
        oracle_cols = [outs[0], outs[1], events[0], outs[2], outs[3],
                       outs[4]]
    result = {}
    for name, got, want in zip(names, streamed, oracle_cols):
        want = np.asarray(want)[live]
        got = np.asarray(got).astype(want.dtype, copy=False)
        result[name] = bool(np.array_equal(got, want, equal_nan=True))
    result["bitwise"] = all(result.values())
    return result


def run_open_load(sim: QueueFlightSim, *, jobs: int = 4096,
                  microbatch: int = 64, slo_ms: float = None,
                  process: ArrivalProcess = None, warmup: bool = True,
                  pipeline_depth: int = 2,
                  seed: Optional[int] = None) -> StreamingReport:
    """Sustained-load driver: feed ``jobs`` open arrivals, measure.

    ``warmup=True`` books one throwaway microbatch on a scratch engine
    first so jit compile never pollutes the sustained numbers (the bench
    tier reports cold/warm compile separately).  Default ``slo_ms`` is
    4x the workload's serial work estimate — a generous latency target
    that stays meaningful across load levels.
    """
    if jobs < 1:
        raise ValueError(f"jobs must be >= 1, got {jobs}")
    if process is None:
        process = PoissonArrivals(sim.rate_hz, seed=sim.seed + 29)
    if slo_ms is None:
        slo_ms = 4.0 * sim.wl.work_est_ws * 1000.0 / max(sim.flight, 1)
    if warmup:
        w = StreamingScheduler(sim, microbatch=microbatch,
                               pipeline_depth=pipeline_depth, seed=seed)
        w.submit(np.linspace(1.0, 2.0, microbatch))
        w.drain()
    eng = StreamingScheduler(sim, microbatch=microbatch,
                             pipeline_depth=pipeline_depth, seed=seed)
    t0 = time.perf_counter()
    left = jobs
    last_ms = 0.0
    while left > 0:
        batch = process.take(min(microbatch, left))
        last_ms = float(batch[-1])
        eng.submit(batch)
        left -= batch.size
    resp, ok = eng.drain()
    wall = time.perf_counter() - t0
    good = resp[ok]
    viol = float(np.mean(~ok | (resp > slo_ms)))
    return StreamingReport(
        jobs=int(resp.size), ok_frac=float(np.mean(ok)), wall_s=wall,
        jobs_per_s=resp.size / wall,
        mean_ms=float(good.mean()) if good.size else float("nan"),
        p50_ms=float(np.percentile(good, 50)) if good.size else float("nan"),
        p99_ms=float(np.percentile(good, 99)) if good.size else float("nan"),
        slo_ms=float(slo_ms), slo_violation_frac=viol,
        horizon_ms=last_ms,
        offered_rate_hz=1000.0 * resp.size / last_ms if last_ms else 0.0)


def stock_open_sojourns(sim: QueueFlightSim, arrivals_ms,
                        seed: int = 0) -> np.ndarray:
    """Idealized stock (task-FCFS, no racing) sojourns on an external
    arrival stream — the reference column of the streaming SLO table.

    A host discrete-event M/G/c: each arriving job expands to its stock
    graph's tasks (dep-free graphs only), every task is served FCFS on
    the earliest-free worker with a fresh service draw (the workload's
    dist/cv + offset) plus a Table-6 lognormal control-plane overhead;
    the job's sojourn is its last task finish minus arrival.  This is the
    *law* of the stock engine for dep-free manifests, not its bitwise
    draw stream — use :class:`QueueFlightSim` for calibrated whole-trace
    stock numbers, this for matched-arrival open-load comparisons
    (EXPERIMENTS.md §streaming's raptor-vs-stock table).
    """
    wl = sim.wl
    sg = wl.stock_graph()
    if sg.has_deps:
        raise ValueError(
            "stock_open_sojourns handles dep-free stock graphs only; "
            f"{wl.name!r} has staged dependencies — use the whole-trace "
            "stock engine")
    arr = np.asarray(arrivals_ms, dtype=np.float64)
    rng = np.random.default_rng(seed)
    K = sg.K
    means = np.asarray(sg.means, dtype=np.float64)
    extras = np.asarray(wl.stock_extras(), dtype=np.float64)

    def unit(n):
        if wl.dist == "exp":
            return rng.exponential(size=n)
        if wl.dist == "pareto":
            alpha = 1.0 + np.sqrt(1.0 + 1.0 / (wl.cv * wl.cv))
            xm = (alpha - 1.0) / alpha
            return xm * rng.uniform(size=n) ** (-1.0 / alpha)
        sigma2 = np.log1p(wl.cv * wl.cv)
        return np.exp(-sigma2 / 2 + np.sqrt(sigma2) * rng.normal(size=n))

    svc = means[None, :] * unit((arr.size, K)) + wl.offset_ms
    svc += extras[None, :] * unit((arr.size, K))
    oh = np.exp(sim.oh_mu + sim.oh_sigma * rng.normal(size=(arr.size, K)))
    free = np.zeros(sim.W)
    resp = np.empty(arr.size)
    for j in range(arr.size):
        fin_max = 0.0
        for k in range(K):
            w = int(np.argmin(free))
            start = max(arr[j], free[w]) + oh[j, k]
            fin = start + svc[j, k]
            free[w] = fin
            fin_max = max(fin_max, fin)
        resp[j] = fin_max - arr[j]
    return resp
