"""Blocked event-replay substrate: chunked max-plus scans over a worker pool.

Every closed-loop engine in :mod:`repro.sim.vector_queue` replays one
sorted event stream per trial against a pool of ``W`` workers, carrying the
per-worker free-at-time vector through a ``lax.scan`` — O(events) of
*sequential* depth that no amount of trial-vmapping or device sharding
(PR 4) can hide, because every step is a tiny dispatch-bound op.  This
module cuts that depth by the block size: the stream is chunked into blocks
of ``B`` events, all bookings inside a block are resolved by a bounded
parallel fixed point, and only the W-vector crosses block boundaries.

Why a fixed point suffices (the blocked max-plus recurrence, derived in
EXPERIMENTS.md):

* an event's booking depends on earlier events ONLY through the worker
  free-at vector ``wf`` it observes, and every booking enters ``wf`` as a
  per-worker **max** (release times on one worker are non-decreasing in
  booking order, so max == overwrite) — a max-plus update;
* therefore the vector event ``i`` observes is reconstructible from the
  block-entry vector plus the bookings of events ``j < i`` alone:
  ``wf_i = max(wf_in, max_{j<i} contrib_j)`` — an *exclusive running max*
  over the block, computable for every event at once (``lax.cummax``);
* that dependency is strictly lower-triangular in the event order, so the
  Jacobi iteration "re-book every event against the vectors reconstructed
  from the previous pass" has a UNIQUE fixed point — the sequential
  schedule itself — and after pass ``p`` the first ``p`` events are exact.
  ``B`` passes are thus always enough (the bound), and the loop exits as
  soon as one pass changes nothing (typically ~(block bookings)/W + 1
  passes: the longest same-worker chain inside the block).

The intra-block work is (B x W) dense arithmetic vectorized across the
(trials x B) plane; sequential depth drops from O(events) to
O(events/B * passes).  ``block=1`` degenerates to the plain event scan
(bit-for-bit the pre-blocking engines) and is kept as the oracle path.

Chaining blocks is itself a max-plus linear recurrence: a resolved block
maps the incoming W-vector by a factored operator (diag, offset) that
composes associatively (``maxplus_compose``), so ``scan="logdepth"``
replaces the O(N/B) sequential block scan with ONE
``lax.associative_scan`` over block summaries per outer pass — O(log N/B)
sequential depth, with a block-level Jacobi (same lower-triangularity
argument, now in block index) supplying exact entry vectors in at most
N/B outer passes.  The summary build + compose also ships as a Pallas
kernel (:mod:`repro.kernels.maxplus_scan`) that keeps the whole operator
tape VMEM-resident on accelerators.

The fused best-fit/earliest-free booking step additionally ships as a
Pallas kernel (:mod:`repro.kernels.queue_booking`) so accelerator runs
resolve whole blocks in VMEM instead of round-tripping HBM per event;
:func:`blocked_bestfit_booking` routes between the two backends.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def booking_contrib(num_workers: int, widx, rel):
    """Dense (..., W) max-map of one event's bookings.

    ``widx``/``rel`` are the event's booked worker indices and release
    times, shape (..., M); a negative index (dead/padded booking) matches
    no worker and contributes ``-inf`` everywhere.  One-hot arithmetic
    only — per-trial dynamic scatters cripple the vmapped replay on CPU.
    """
    oh = widx[..., None] == jnp.arange(num_workers)
    return jnp.max(jnp.where(oh, rel[..., None], -jnp.inf), axis=-2)


def apply_bookings(wf, widx, rel):
    """Fold one event's bookings into the free-at vector (max-plus)."""
    return jnp.maximum(wf, booking_contrib(wf.shape[-1], widx, rel))


def exclusive_running_max(contrib, wf_in):
    """Per-event observed W-vectors: row ``i`` is ``max(wf_in,
    max_{j<i} contrib[j])`` — the worker vector event ``i`` would see had
    events ``0..i-1`` booked exactly ``contrib[0..i-1]``."""
    run = lax.cummax(contrib, axis=0)
    prev = jnp.concatenate(
        [jnp.full((1,) + run.shape[1:], -jnp.inf, run.dtype), run[:-1]],
        axis=0)
    return jnp.maximum(wf_in[None, :], prev)


# --------------------------------------------------------------------------
# factored W x W max-plus block operators (the log-depth summaries)
# --------------------------------------------------------------------------
# A resolved block acts on the carried free-at vector as a max-plus linear
# map.  In full generality that map is a W x W matrix, but every map the
# replay produces factors as (diag, offset): apply((d, b), wf) =
# max(wf + d, b) elementwise — the diagonal shifts what the block leaves of
# the incoming vector, the offset is the block's own bookings.  Factored
# operators compose closed-form in O(W) (compose below) and the composition
# is associative, so a whole stream's prefix maps come out of ONE
# `lax.associative_scan` at O(log nb) sequential depth.
#
# Bitwise note: the engines only ever emit diag = 0 operators (a booking
# REPLACES a worker's free-at time; it never shifts it), and with d == 0
# the compose degenerates to an elementwise float max — exactly
# associative in floats, which is what lets scan="logdepth" stay bitwise
# against the sequential oracle.  The general d != 0 form is kept (and
# property-tested) because it is the algebra the Pallas kernel implements.

def maxplus_identity(num_workers: int, dtype=jnp.float32):
    """The do-nothing block operator: d = 0, b = -inf."""
    return (jnp.zeros((num_workers,), dtype),
            jnp.full((num_workers,), -jnp.inf, dtype))


def maxplus_compose(first, then):
    """Operator for "apply ``first``, then ``then``" (elementwise, O(W)).

    ``apply(compose(first, then), wf) == apply(then, apply(first, wf))``:
    max(max(wf + d1, b1) + d2, b2) = max(wf + (d1 + d2), max(b1 + d2, b2)).
    """
    d1, b1 = first
    d2, b2 = then
    return d1 + d2, jnp.maximum(b1 + d2, b2)


def maxplus_apply(op, wf):
    """Push a free-at vector through a factored block operator."""
    d, b = op
    return jnp.maximum(wf + d, b)


def block_summary(num_workers: int, widx, rel):
    """Offset part of a resolved block's operator: the per-worker max of
    its booking contributions, shape (..., W) from (..., B, M) estimates.
    The engines' diagonal part is identically 0 (see module note)."""
    return jnp.max(booking_contrib(num_workers, widx, rel), axis=-2)


def maxplus_prefix_entries(diag, off, wf0, *, backend: str = "xla",
                           interpret=None):
    """Entry vectors of every block from one associative prefix scan.

    ``diag``/``off``: (nb, W) factored per-block operators, ``wf0``: (W,)
    the stream's entry vector.  Returns ``(entries, wf_out)``: row ``k``
    of ``entries`` (nb, W) is the vector block ``k`` begins with —
    ``apply(op_0 ∘ … ∘ op_{k-1}, wf0)``, row 0 is ``wf0`` itself — and
    ``wf_out`` is the whole stream's exit vector.  ``backend="pallas"``
    routes through :mod:`repro.kernels.maxplus_scan` (the VMEM-resident
    doubling scan); ``"xla"`` is ``jax.lax.associative_scan``.
    """
    if backend == "pallas":
        from repro.kernels.maxplus_scan.ops import maxplus_entries
        ent, wf_out = maxplus_entries(diag[None], off[None], wf0[None],
                                      interpret=interpret)
        return ent[0], wf_out[0]
    if backend != "xla":
        raise ValueError(f"unknown summary backend {backend!r}")
    pd, pb = lax.associative_scan(maxplus_compose, (diag, off), axis=0)
    entries = jnp.concatenate(
        [wf0[None], maxplus_apply((pd[:-1], pb[:-1]), wf0[None])], axis=0)
    return entries, maxplus_apply((pd[-1], pb[-1]), wf0)


# --------------------------------------------------------------------------
# intra-block resolvers (exact, shape-generic over the block length)
# --------------------------------------------------------------------------

def _fixpoint_resolver(body, W):
    """Bounded parallel Jacobi over one block: re-book every event against
    the per-event W-vectors reconstructed from the previous pass, until the
    OBSERVED vectors converge (bitwise).  Convergence of the observed rows
    — not merely of the booking estimates — is the right exit test: a dead
    event's irrelevant worker pick may flap between passes without ever
    changing what any event observes, and conversely equal bookings under
    unequal observations would exit with stale outputs.  The returned
    ``(est, out)`` are always evaluated at the converged rows."""
    vbody = jax.vmap(body)

    def resolve(wf, ev):
        nev = jax.tree_util.tree_leaves(ev)[0].shape[0]

        def rows_of(est):
            return exclusive_running_max(booking_contrib(W, *est), wf)

        # pass 1 observes the carried vector alone (the empty-prefix rows)
        rows0 = jnp.broadcast_to(wf, (nev, W))
        est1, out1 = vbody(rows0, ev)

        def cond(c):
            p, rows, used = c[0], c[1], c[2]
            return jnp.any(rows != used) & (p < nev)

        def again(c):
            p, rows = c[0], c[1]
            est2, out2 = vbody(rows, ev)
            return p + 1, rows_of(est2), rows, est2, out2

        _, _, _, est, out = lax.while_loop(
            cond, again, (jnp.asarray(1), rows_of(est1), rows0, est1, out1))
        return est, out

    return resolve


def _unrolled_resolver(body, unroll=None):
    """Resolve one block as a fused straight-line sequential region; also
    returns the booking estimates so the caller can summarize the block."""
    def resolve(wf, ev):
        nev = jax.tree_util.tree_leaves(ev)[0].shape[0]

        def step(w, e):
            (widx, rel), out = body(w, e)
            return apply_bookings(w, widx, rel), ((widx, rel), out)

        _, (est, out) = lax.scan(
            step, wf, ev, unroll=nev if unroll is None else min(unroll, nev))
        return est, out

    return resolve


def _tree_concat(a, b):
    return jax.tree_util.tree_map(
        lambda x, y: jnp.concatenate([x, y], axis=0), a, b)


def blocked_event_replay(body, wf0, events, *, block: int,
                         resolver: str = "fixpoint", unroll: int = 1,
                         scan: str = "seq", summary_backend: str = "xla",
                         interpret=None):
    """Replay a sorted event stream in blocks, carrying only the W-vector.

    ``body(wf, event) -> ((widx, rel), out)`` books one event against the
    worker free-at vector ``wf`` it observes: ``widx`` (M,) int are the
    booked workers (< 0 books nothing — the dead/padded convention),
    ``rel`` (M,) their release times (must be ``-inf`` wherever the event
    must not touch the pool), ``out`` an arbitrary output pytree.  Events
    is a pytree with leading axis N (the per-trial stream, already
    sorted).  ``block`` need not divide N: the ragged tail is resolved as
    one final partial block — no phantom events are ever synthesized.
    ``block=0`` picks the adaptive log-depth split (``ceil(n/3)``).

    ``block=1`` runs the plain sequential scan (bit-identical to the
    pre-blocking engines; ``unroll`` trims its per-step dispatch cost) —
    the oracle path.  For ``block > 1`` the intra-block resolver is:

    * ``"fixpoint"`` — the bounded parallel Jacobi described in the
      module docstring: exact in at most ``block`` passes, early-exit on
      convergence of the observed per-event W-vectors, all comparisons
      bitwise so the fixed point IS the sequential schedule.  Pass count
      tracks the longest intra-block dependency chain, so this is the
      depth-reduction mode: O(N/B·p) runtime steps, each (trials x
      B)-wide.  When bookings are placement-coupled (the raptor HA
      discipline: which worker is free decides the AZ-shared draws)
      chains approach the block length and the mode loses its edge —
      measured in EXPERIMENTS.md.
    * ``"unrolled"`` — resolve the block as one fused straight-line
      region (scan unrolling): events inside a block resolve sequentially
      in-register instead of iteratively in parallel.

    ``scan`` picks how resolved blocks chain across the stream:

    * ``"seq"`` — a ``lax.scan`` over blocks carries the W-vector:
      O(N/B) sequential depth.
    * ``"logdepth"`` — every block is summarized as a factored W x W
      max-plus operator (offset = the block's booking contributions) and
      ALL block entry vectors come out of one ``lax.associative_scan``
      over the summaries — O(log(N/B)) sequential depth per pass.  Entry
      vectors feed back into a block-level Jacobi iteration (every block
      re-resolves against its latest entry estimate, vmapped across
      blocks) whose fixed point is unique by the same strict
      lower-triangularity argument, now in block index: after pass ``p``
      blocks ``0..p`` are exact, so ``nb`` passes always suffice and the
      loop exits as soon as the entries stop changing.  The intra-block
      resolvers are reused unchanged; ``summary_backend`` routes the
      summary prefix scan ("xla" or the "pallas" VMEM kernel).

    Every (resolver, scan) configuration is bitwise-identical to the
    ``block=1`` oracle scan (tests/test_queue_properties.py).  Returns
    ``(wf_final, outs)`` with each out leaf stacked along the event axis.
    """
    W = int(wf0.shape[-1])
    n = int(jax.tree_util.tree_leaves(events)[0].shape[0])
    block = int(block)
    if not block:
        # adaptive split (the auto_config log-depth host default): two
        # Jacobi blocks + an equal ragged tail — ceil(n/3).  More blocks
        # multiply total work by the outer pass count (which is exactly
        # nb under bitwise choice coupling), fewer waste the tail's
        # single resolve; see EXPERIMENTS.md §log-depth.
        block = max(1, -(-n // 3))
    if scan not in ("seq", "logdepth"):
        raise ValueError(f"unknown block scan mode {scan!r}")

    if block <= 1 or (resolver == "unrolled" and scan == "seq"):
        def step(wf, ev):
            (widx, rel), out = body(wf, ev)
            return apply_bookings(wf, widx, rel), out
        return lax.scan(step, wf0, events,
                        unroll=unroll if block <= 1 else block)

    if resolver == "fixpoint":
        resolve = _fixpoint_resolver(body, W)
    elif resolver == "unrolled":
        # small blocks fuse into one straight-line region; big blocks cap
        # the codegen (compile cost grows with the unroll factor) and loop
        # a partially-unrolled scan instead — same schedule bitwise
        resolve = _unrolled_resolver(
            body, None if block <= 32 else max(unroll, 8))
    else:
        raise ValueError(f"unknown block resolver {resolver!r}")

    nb, rem = divmod(n, block)
    split = n - rem
    main = jax.tree_util.tree_map(
        lambda a: a[:split].reshape((nb, block) + a.shape[1:]), events)
    tail = (jax.tree_util.tree_map(lambda a: a[split:], events)
            if rem else None)

    def resolve_step(wf, ev):
        est, out = resolve(wf, ev)
        return jnp.maximum(wf, jnp.max(booking_contrib(W, *est), axis=0)), out

    if scan == "seq":
        if nb:
            wf_r, outs = lax.scan(resolve_step, wf0, main)
            outs = jax.tree_util.tree_map(
                lambda a: a.reshape((split,) + a.shape[2:]), outs)
        else:
            wf_r, outs = wf0, None
    else:
        if nb:
            wf_r, outs = _logdepth_replay(resolve, wf0, main, nb, W,
                                          summary_backend, interpret)
            outs = jax.tree_util.tree_map(
                lambda a: a.reshape((split,) + a.shape[2:]), outs)
        else:
            wf_r, outs = wf0, None
    if rem:
        wf_r, out_t = resolve_step(wf_r, tail)
        outs = out_t if outs is None else _tree_concat(outs, out_t)
    return wf_r, outs


def _logdepth_replay(resolve, wf0, ev_blocks, nb, W, summary_backend,
                     interpret):
    """Block-level Jacobi over entry vectors with the associative max-plus
    prefix supplying every block's entry at O(log nb) depth per pass.

    Invariant at exit: the returned ``(est, out)`` were produced by a
    resolve pass whose entry estimates equal the entries those estimates
    regenerate — the unique fixed point, i.e. the sequential schedule.
    Summaries are offset-only (diag = 0): a block's effect on the carried
    vector is a pure elementwise max with its booking contributions, so
    the prefix scan composes float maxes only — exactly associative,
    keeping the whole mode bitwise against the sequential oracle.
    """
    vres = jax.vmap(resolve)
    zeros = jnp.zeros((nb, W), wf0.dtype)

    def prefix(est):
        off = block_summary(W, *est)            # (nb, W)
        return maxplus_prefix_entries(zeros, off, wf0,
                                      backend=summary_backend,
                                      interpret=interpret)

    entries0 = jnp.broadcast_to(wf0, (nb, W))
    est0, out0 = vres(entries0, ev_blocks)
    entries1, wf1 = prefix(est0)

    def cond(c):
        p, entries, used = c[0], c[1], c[2]
        return jnp.any(entries != used) & (p < nb)

    def again(c):
        p, entries = c[0], c[1]
        est, out = vres(entries, ev_blocks)
        entries2, wf2 = prefix(est)
        return p + 1, entries2, entries, est, out, wf2

    _, _, _, est, out, wf_out = lax.while_loop(
        cond, again, (jnp.asarray(1), entries1, entries0, est0, out0, wf1))
    return wf_out, out


# --------------------------------------------------------------------------
# the shared booking step (task-FCFS stock discipline) + its blocked driver
# --------------------------------------------------------------------------

def bestfit_book_step(wf, ready, service):
    """Book one ready task: best-fit among free workers, earliest-free
    fallback when all are busy.

    Fused key (the PR-3 trick): free workers (``wf <= ready``) rank by
    ``wf`` — latest-freed-but-eligible wins, all keys >= 0 — busy workers
    by ``-wf`` (< 0, so they lose to any free worker, and among them
    ``argmax(-wf)`` IS the earliest-free fallback); ``-max(key)`` then
    equals the booking delay floor, so ``start = max(ready, -max(key))``
    needs no gather.  A ``ready`` of ``inf`` (unmaterialized / padding)
    books nothing: worker -1, start/fin inf.  Returns (worker, start, fin).
    """
    live = ~jnp.isinf(ready)
    key = jnp.where(wf <= ready, wf, -wf)
    w = jnp.argmax(key)
    start = jnp.maximum(ready, -jnp.max(key))
    fin = start + service
    return (jnp.where(live, w, -1), jnp.where(live, start, jnp.inf),
            jnp.where(live, fin, jnp.inf))


def blocked_bestfit_booking(wf0, ready, service, *, block: int,
                            full: bool = True, unroll: int = 16,
                            backend: str = "scan", interpret=None,
                            resolver: str = "fixpoint", scan: str = "seq",
                            summary_backend: str = "xla"):
    """Resolve one trial's whole ready-sorted stream of best-fit bookings.

    ``ready``/``service`` are (N,) (any N — a ragged tail resolves as one
    final partial block); ``wf0`` the (W,) entry free-at vector.  Returns
    ``(fin, start, worker)`` when ``full`` else ``(fin,)`` — the non-full
    form lets the stock fixed point over stage depth skip two (N,)-sized
    outputs per estimation pass.

    ``backend="scan"`` runs :func:`blocked_event_replay` (with its
    ``resolver``/``scan``/``summary_backend`` knobs passed through);
    ``"pallas"`` dispatches the fused intra-block kernel
    (:mod:`repro.kernels.queue_booking`), which keeps the whole block
    resolution in VMEM on accelerators (``interpret`` defaults per
    :func:`repro.kernels._compat.interpret_default`, so the same code path
    runs — and is CI-tested — on CPU).
    """
    if backend == "pallas":
        from repro.kernels.queue_booking.ops import book_stream
        fin, start, worker, _ = book_stream(
            ready[None], service[None], wf0[None], block=block,
            interpret=interpret)
        return (fin[0], start[0], worker[0]) if full else (fin[0],)
    if backend != "scan":
        raise ValueError(f"unknown booking backend {backend!r}")

    def body(wf, ev):
        w, start, fin = bestfit_book_step(wf, *ev)
        out = (fin, start, w) if full else (fin,)
        # widx=-1 already gates dead events out of the pool; fin is their
        # (constant) inf, so the convergence check stays stable
        return (w[None], fin[None]), out

    _, outs = blocked_event_replay(body, wf0, (ready, service),
                                   block=block, unroll=unroll,
                                   resolver=resolver, scan=scan,
                                   summary_backend=summary_backend,
                                   interpret=interpret)
    return outs


def blocked_sorted_booking(wf0, ready, service, *, block: int):
    """Finish times of a ready-sorted best-fit booking stream, resolved
    block-parallel through the order-statistic form of the recurrence.

    Under ready-sorted FCFS the booked *worker* is interchangeable (any
    policy that books a free worker when one exists and the earliest-free
    otherwise leaves the same multiset of future-relevant free-at times —
    EXPERIMENTS.md), so only the sorted pool matters and the start time
    collapses to an order statistic:

        st_i = max(r_i, c_i-th smallest of (pool_in ∪ {fin_j : j < i}))

    with ``c_i`` the count of live events through ``i``.  That dependency
    is strictly lower-triangular in ``fin``, so the same bounded Jacobi
    fixed point applies — but errors now propagate only along *same-worker
    chains* (a fin estimate that keeps its rank perturbs nothing), so the
    pass count stays near (block bookings)/W even at high utilisation,
    where the worker-identity Jacobi of :func:`blocked_event_replay`
    degrades toward one event per pass.  The cost: worker ids are never
    materialized — this is the measurement path; the trace path resolves
    ids through the generic fixed point instead.

    Each pass is one sort of the (W + B) pool tagged by availability rank
    plus a cumulative-count selection — the "chunked max-plus scan" of the
    blocked substrate.  Returns ``(fin,)`` shaped like ``ready`` (inf for
    dead events); bitwise equal to the sequential scan's finish times.
    """
    W = int(wf0.shape[-1])
    n = int(ready.shape[0])
    block = int(block)

    def resolver_at(blk):
        idx = jnp.arange(blk)
        avail = jnp.concatenate([jnp.zeros(W, jnp.int32),
                                 1 + idx.astype(jnp.int32)])

        def resolve(pool, ev):
            r, s = ev
            live = ~jnp.isinf(r)
            c = jnp.cumsum(live)        # live bookings through event i

            def one_pass(fin):
                vals = jnp.concatenate([pool, fin])
                order = jnp.argsort(vals)
                v_s, a_s = vals[order], avail[order]
                # element q is in event i's pool iff its availability rank
                # a_s[q] <= i (0 = entry pool, j+1 = fin_j); the c_i-th
                # included element of the sorted tape IS the order statistic
                incl = a_s[None, :] <= idx[:, None]
                cnt = jnp.cumsum(incl, axis=1)
                hit = incl & (cnt == c[:, None])
                sig = jnp.sum(jnp.where(hit, v_s, 0.0), axis=1)
                st = jnp.maximum(r, sig)
                return jnp.where(live, st + s, jnp.inf)

            fin0 = jnp.where(live, r + s, jnp.inf)  # zero-queueing bound
            fin1 = one_pass(fin0)

            def cond(carry):
                p, fin, prev = carry
                return jnp.any(fin != prev) & (p < blk)

            def again(carry):
                p, fin, _ = carry
                return p + 1, one_pass(fin), fin

            _, fin, _ = lax.while_loop(cond, again,
                                       (jnp.asarray(1), fin1, fin0))
            # block exit: the c_B consumed values are exactly the c_B
            # smallest of the pool ∪ fins (consume-min equivalence);
            # keep the rest
            tape = jnp.sort(jnp.concatenate([pool, fin]))
            return lax.dynamic_slice(tape, (c[-1],), (W,)), fin

        return resolve

    # ragged tail: the remainder resolves as one final partial block
    # against the carried pool — never via phantom events
    nb, rem = divmod(n, block)
    split = n - rem
    pool = jnp.sort(wf0)
    if nb:
        pool, fin = lax.scan(
            resolver_at(block), pool,
            jax.tree_util.tree_map(lambda a: a[:split].reshape(nb, block),
                                   (ready, service)))
        fin = fin.reshape(split)
    else:
        fin = jnp.zeros((0,), ready.dtype)
    if rem:
        _, fin_t = resolver_at(rem)(pool, (ready[split:], service[split:]))
        fin = jnp.concatenate([fin, fin_t])
    return (fin,)


def stock_booking_fins(wf0, ready, service, *, block: int,
                       backend: str = "scan", interpret=None,
                       scan: str = "seq", summary_backend: str = "xla"):
    """Finish times only — the form the stock stage-depth fixed point
    consumes on every estimation pass.  Dispatch: ``block <= 1`` runs the
    sequential oracle scan, larger blocks the order-statistic resolver
    (``scan="seq"``) or the log-depth generic replay (``scan="logdepth"``),
    ``backend="pallas"`` the fused VMEM kernel."""
    if backend == "pallas" or block <= 1:
        return blocked_bestfit_booking(
            wf0, ready, service, block=max(block, 1), full=False,
            backend=backend, interpret=interpret)
    if scan == "logdepth":
        return blocked_bestfit_booking(
            wf0, ready, service, block=block, full=False, backend=backend,
            resolver="unrolled", scan="logdepth",
            summary_backend=summary_backend, interpret=interpret)
    return blocked_sorted_booking(wf0, ready, service, block=block)
