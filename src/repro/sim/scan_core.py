"""Blocked event-replay substrate: chunked max-plus scans over a worker pool.

Every closed-loop engine in :mod:`repro.sim.vector_queue` replays one
sorted event stream per trial against a pool of ``W`` workers, carrying the
per-worker free-at-time vector through a ``lax.scan`` — O(events) of
*sequential* depth that no amount of trial-vmapping or device sharding
(PR 4) can hide, because every step is a tiny dispatch-bound op.  This
module cuts that depth by the block size: the stream is chunked into blocks
of ``B`` events, all bookings inside a block are resolved by a bounded
parallel fixed point, and only the W-vector crosses block boundaries.

Why a fixed point suffices (the blocked max-plus recurrence, derived in
EXPERIMENTS.md):

* an event's booking depends on earlier events ONLY through the worker
  free-at vector ``wf`` it observes, and every booking enters ``wf`` as a
  per-worker **max** (release times on one worker are non-decreasing in
  booking order, so max == overwrite) — a max-plus update;
* therefore the vector event ``i`` observes is reconstructible from the
  block-entry vector plus the bookings of events ``j < i`` alone:
  ``wf_i = max(wf_in, max_{j<i} contrib_j)`` — an *exclusive running max*
  over the block, computable for every event at once (``lax.cummax``);
* that dependency is strictly lower-triangular in the event order, so the
  Jacobi iteration "re-book every event against the vectors reconstructed
  from the previous pass" has a UNIQUE fixed point — the sequential
  schedule itself — and after pass ``p`` the first ``p`` events are exact.
  ``B`` passes are thus always enough (the bound), and the loop exits as
  soon as one pass changes nothing (typically ~(block bookings)/W + 1
  passes: the longest same-worker chain inside the block).

The intra-block work is (B x W) dense arithmetic vectorized across the
(trials x B) plane; sequential depth drops from O(events) to
O(events/B * passes).  ``block=1`` degenerates to the plain event scan
(bit-for-bit the pre-blocking engines) and is kept as the oracle path.

The fused best-fit/earliest-free booking step additionally ships as a
Pallas kernel (:mod:`repro.kernels.queue_booking`) so accelerator runs
resolve whole blocks in VMEM instead of round-tripping HBM per event;
:func:`blocked_bestfit_booking` routes between the two backends.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def booking_contrib(num_workers: int, widx, rel):
    """Dense (..., W) max-map of one event's bookings.

    ``widx``/``rel`` are the event's booked worker indices and release
    times, shape (..., M); a negative index (dead/padded booking) matches
    no worker and contributes ``-inf`` everywhere.  One-hot arithmetic
    only — per-trial dynamic scatters cripple the vmapped replay on CPU.
    """
    oh = widx[..., None] == jnp.arange(num_workers)
    return jnp.max(jnp.where(oh, rel[..., None], -jnp.inf), axis=-2)


def apply_bookings(wf, widx, rel):
    """Fold one event's bookings into the free-at vector (max-plus)."""
    return jnp.maximum(wf, booking_contrib(wf.shape[-1], widx, rel))


def exclusive_running_max(contrib, wf_in):
    """Per-event observed W-vectors: row ``i`` is ``max(wf_in,
    max_{j<i} contrib[j])`` — the worker vector event ``i`` would see had
    events ``0..i-1`` booked exactly ``contrib[0..i-1]``."""
    run = lax.cummax(contrib, axis=0)
    prev = jnp.concatenate(
        [jnp.full((1,) + run.shape[1:], -jnp.inf, run.dtype), run[:-1]],
        axis=0)
    return jnp.maximum(wf_in[None, :], prev)


def blocked_event_replay(body, wf0, events, *, block: int,
                         resolver: str = "fixpoint", unroll: int = 1):
    """Replay a sorted event stream in blocks, carrying only the W-vector.

    ``body(wf, event) -> ((widx, rel), out)`` books one event against the
    worker free-at vector ``wf`` it observes: ``widx`` (M,) int are the
    booked workers (< 0 books nothing — the dead/padded convention),
    ``rel`` (M,) their release times (must be ``-inf`` wherever the event
    must not touch the pool), ``out`` an arbitrary output pytree.  Events
    is a pytree with leading axis N (the per-trial stream, already sorted
    and — for the fixpoint resolver — padded to a multiple of ``block``).

    ``block <= 1`` runs the plain sequential scan (bit-identical to the
    pre-blocking engines; ``unroll`` trims its per-step dispatch cost) —
    the oracle path.  For ``block > 1`` the intra-block resolver is:

    * ``"fixpoint"`` — the bounded parallel Jacobi described in the
      module docstring: exact in at most ``block`` passes, early-exit on
      convergence, all comparisons bitwise so the fixed point IS the
      sequential schedule.  Pass count tracks the longest intra-block
      dependency chain, so this is the depth-reduction mode: O(N/B·p)
      runtime steps, each (trials x B)-wide.  When bookings are
      placement-coupled (the raptor HA discipline: which worker is free
      decides the AZ-shared draws) chains approach the block length and
      the mode loses its edge — measured in EXPERIMENTS.md.
    * ``"unrolled"`` — resolve the block as one fused straight-line
      region (scan unrolling): the runtime loop still has depth N/B with
      only the W-vector carried between iterations, but events inside a
      block resolve sequentially in-register instead of iteratively in
      parallel.  The throughput mode for placement-coupled streams.

    Both resolvers are bitwise-identical to the ``block=1`` oracle scan
    (tests/test_queue_properties.py).  Returns ``(wf_final, outs)`` with
    each out leaf stacked along the (padded) event axis.
    """
    W = int(wf0.shape[-1])
    n = int(jax.tree_util.tree_leaves(events)[0].shape[0])
    block = int(block)

    if block <= 1 or resolver == "unrolled":
        def step(wf, ev):
            (widx, rel), out = body(wf, ev)
            return apply_bookings(wf, widx, rel), out
        return lax.scan(step, wf0, events,
                        unroll=unroll if block <= 1 else block)

    if resolver != "fixpoint":
        raise ValueError(f"unknown block resolver {resolver!r}")
    if n % block:
        raise ValueError(
            f"event stream length {n} is not a multiple of block={block}; "
            f"pad the stream (dead events: ready=inf / widx=-1)")
    nb = n // block
    ev_blocks = jax.tree_util.tree_map(
        lambda a: a.reshape((nb, block) + a.shape[1:]), events)
    vbody = jax.vmap(body)

    def resolve_block(wf, ev):
        def one_pass(est):
            rows = exclusive_running_max(booking_contrib(W, *est), wf)
            return vbody(rows, ev)

        # pass 1 observes the carried vector alone (an empty-prefix
        # estimate), which doubles as the shape probe for the estimates
        est1, out1 = vbody(jnp.broadcast_to(wf, (block, W)), ev)
        est0 = (jnp.full_like(est1[0], -1),
                jnp.full_like(est1[1], -jnp.inf))

        def cond(c):
            p, est, prev, _ = c
            changed = (jnp.any(est[0] != prev[0])
                       | jnp.any(est[1] != prev[1]))
            return changed & (p < block)

        def again(c):
            p, est, _, _ = c
            est2, out2 = one_pass(est)
            return p + 1, est2, est, out2

        _, est, _, out = lax.while_loop(
            cond, again, (jnp.asarray(1), est1, est0, out1))
        wf2 = jnp.maximum(wf, jnp.max(booking_contrib(W, *est), axis=0))
        return wf2, out

    wf_final, outs = lax.scan(resolve_block, wf0, ev_blocks)
    outs = jax.tree_util.tree_map(
        lambda a: a.reshape((n,) + a.shape[2:]), outs)
    return wf_final, outs


# --------------------------------------------------------------------------
# the shared booking step (task-FCFS stock discipline) + its blocked driver
# --------------------------------------------------------------------------

def bestfit_book_step(wf, ready, service):
    """Book one ready task: best-fit among free workers, earliest-free
    fallback when all are busy.

    Fused key (the PR-3 trick): free workers (``wf <= ready``) rank by
    ``wf`` — latest-freed-but-eligible wins, all keys >= 0 — busy workers
    by ``-wf`` (< 0, so they lose to any free worker, and among them
    ``argmax(-wf)`` IS the earliest-free fallback); ``-max(key)`` then
    equals the booking delay floor, so ``start = max(ready, -max(key))``
    needs no gather.  A ``ready`` of ``inf`` (unmaterialized / padding)
    books nothing: worker -1, start/fin inf.  Returns (worker, start, fin).
    """
    live = ~jnp.isinf(ready)
    key = jnp.where(wf <= ready, wf, -wf)
    w = jnp.argmax(key)
    start = jnp.maximum(ready, -jnp.max(key))
    fin = start + service
    return (jnp.where(live, w, -1), jnp.where(live, start, jnp.inf),
            jnp.where(live, fin, jnp.inf))


def blocked_bestfit_booking(wf0, ready, service, *, block: int,
                            full: bool = True, unroll: int = 16,
                            backend: str = "scan", interpret=None):
    """Resolve one trial's whole ready-sorted stream of best-fit bookings.

    ``ready``/``service`` are (N,) with N a multiple of ``block`` (pad with
    ready=inf, service=0); ``wf0`` the (W,) entry free-at vector.  Returns
    ``(fin, start, worker)`` when ``full`` else ``(fin,)`` — the non-full
    form lets the stock fixed point over stage depth skip two (N,)-sized
    outputs per estimation pass.

    ``backend="scan"`` runs :func:`blocked_event_replay`; ``"pallas"``
    dispatches the fused intra-block kernel
    (:mod:`repro.kernels.queue_booking`), which keeps the whole block
    resolution in VMEM on accelerators (``interpret`` defaults per
    :func:`repro.kernels._compat.interpret_default`, so the same code path
    runs — and is CI-tested — on CPU).
    """
    if backend == "pallas":
        from repro.kernels.queue_booking.ops import book_stream
        fin, start, worker, _ = book_stream(
            ready[None], service[None], wf0[None], block=block,
            interpret=interpret)
        return (fin[0], start[0], worker[0]) if full else (fin[0],)
    if backend != "scan":
        raise ValueError(f"unknown booking backend {backend!r}")

    def body(wf, ev):
        w, start, fin = bestfit_book_step(wf, *ev)
        out = (fin, start, w) if full else (fin,)
        # widx=-1 already gates dead events out of the pool; fin is their
        # (constant) inf, so the convergence check stays stable
        return (w[None], fin[None]), out

    _, outs = blocked_event_replay(body, wf0, (ready, service),
                                   block=block, unroll=unroll)
    return outs


def blocked_sorted_booking(wf0, ready, service, *, block: int):
    """Finish times of a ready-sorted best-fit booking stream, resolved
    block-parallel through the order-statistic form of the recurrence.

    Under ready-sorted FCFS the booked *worker* is interchangeable (any
    policy that books a free worker when one exists and the earliest-free
    otherwise leaves the same multiset of future-relevant free-at times —
    EXPERIMENTS.md), so only the sorted pool matters and the start time
    collapses to an order statistic:

        st_i = max(r_i, c_i-th smallest of (pool_in ∪ {fin_j : j < i}))

    with ``c_i`` the count of live events through ``i``.  That dependency
    is strictly lower-triangular in ``fin``, so the same bounded Jacobi
    fixed point applies — but errors now propagate only along *same-worker
    chains* (a fin estimate that keeps its rank perturbs nothing), so the
    pass count stays near (block bookings)/W even at high utilisation,
    where the worker-identity Jacobi of :func:`blocked_event_replay`
    degrades toward one event per pass.  The cost: worker ids are never
    materialized — this is the measurement path; the trace path resolves
    ids through the generic fixed point instead.

    Each pass is one sort of the (W + B) pool tagged by availability rank
    plus a cumulative-count selection — the "chunked max-plus scan" of the
    blocked substrate.  Returns ``(fin,)`` shaped like ``ready`` (inf for
    dead events); bitwise equal to the sequential scan's finish times.
    """
    W = int(wf0.shape[-1])
    n = int(ready.shape[0])
    block = int(block)
    if n % block:
        raise ValueError(f"stream length {n} not a multiple of {block}")
    nb = n // block
    idx = jnp.arange(block)
    avail = jnp.concatenate([jnp.zeros(W, jnp.int32),
                             1 + idx.astype(jnp.int32)])

    def resolve(pool, ev):
        r, s = ev
        live = ~jnp.isinf(r)
        c = jnp.cumsum(live)            # live bookings through event i

        def one_pass(fin):
            vals = jnp.concatenate([pool, fin])
            order = jnp.argsort(vals)
            v_s, a_s = vals[order], avail[order]
            # element q is in event i's pool iff its availability rank
            # a_s[q] <= i (0 = entry pool, j+1 = fin_j); the c_i-th
            # included element of the sorted tape IS the order statistic
            incl = a_s[None, :] <= idx[:, None]
            cnt = jnp.cumsum(incl, axis=1)
            hit = incl & (cnt == c[:, None])
            sig = jnp.sum(jnp.where(hit, v_s, 0.0), axis=1)
            st = jnp.maximum(r, sig)
            return jnp.where(live, st + s, jnp.inf)

        fin0 = jnp.where(live, r + s, jnp.inf)      # zero-queueing bound
        fin1 = one_pass(fin0)

        def cond(carry):
            p, fin, prev = carry
            return jnp.any(fin != prev) & (p < block)

        def again(carry):
            p, fin, _ = carry
            return p + 1, one_pass(fin), fin

        _, fin, _ = lax.while_loop(cond, again, (jnp.asarray(1), fin1, fin0))
        # block exit: the c_B consumed values are exactly the c_B smallest
        # of the pool ∪ fins (consume-min equivalence); keep the rest
        tape = jnp.sort(jnp.concatenate([pool, fin]))
        return lax.dynamic_slice(tape, (c[-1],), (W,)), fin

    _, fin = lax.scan(resolve, jnp.sort(wf0), jax.tree_util.tree_map(
        lambda a: a.reshape(nb, block), (ready, service)))
    return (fin.reshape(n),)


def stock_booking_fins(wf0, ready, service, *, block: int,
                       backend: str = "scan", interpret=None):
    """Finish times only — the form the stock stage-depth fixed point
    consumes on every estimation pass.  Dispatch: ``block <= 1`` runs the
    sequential oracle scan, larger blocks the order-statistic resolver,
    ``backend="pallas"`` the fused VMEM kernel."""
    if backend == "pallas" or block <= 1:
        return blocked_bestfit_booking(
            wf0, ready, service, block=max(block, 1), full=False,
            backend=backend, interpret=interpret)
    return blocked_sorted_booking(wf0, ready, service, block=block)
