"""JAX-vectorized Monte-Carlo flight simulator: thousands of independent
invocations of the AZ-correlated service-time model at once.

The scalar :class:`repro.sim.flights.FlightSim` is an event-driven queueing
simulator — faithful, but minutes per configuration.  This module draws the
paper's correlation model (``Z = rho*S + (1-rho)*X``, S shared per AZ — see
``sim/cluster.py``) for a whole batch of trials as dense tensors and replays
each flight's race with a fixed-trip ``lax.scan`` under ``vmap``, so a
(flight size × AZ count × rho × load) sweep runs on-device in milliseconds.

Scope: this module is the OPEN-LOOP tier — independent-task manifests
(ssh-keygen, the Figure-8 reliability probes), one trial = one invocation
on an otherwise idle cluster, i.e. the zero-queueing limit of the scalar
sim.  The closed-loop tier lives in :mod:`repro.sim.vector_queue`: batched
M/G/c worker queues replayed over whole Poisson arrival streams, plus the
DAG manifests (wordcount, thumbnail) via per-member dependency masks — so
every load-dependent paper figure (fig6, fig7, Table 8 at real
utilisation) also runs on-device.  Config sweeps are batched in both
tiers and routed through the device-sharded driver in
:mod:`repro.sim.sweeps`: :func:`sweep_pairs` pads-and-masks over flight
size and traces rho/AZ-count/overhead so a whole (flight x AZ x rho x
load) grid shares a handful of compilations instead of paying ~1.5s of
XLA compile per point (BENCH_sim.json), with the config axis sharded over
the jax device mesh, and ``sequences="random"`` swaps the §3.3.3 cyclic
shifts for per-trial random orders (the ROADMAP F>>K paper-gap probe).
The scalar sim remains the oracle: ``tests/test_sim_vector.py`` and
``tests/test_sim_queue.py`` check seeded agreement on mean response,
tail percentiles, and failure rate from low through high utilisation.

Flight semantics mirror the scalar sim exactly (paper §3.3.3–§3.3.4):

* member ``m`` runs the task list cyclically shifted by ``m % num_tasks``;
* the first error-free completion of a task is broadcast, peers running it
  are preempted and restart after the half-RTT stream latency;
* a failed attempt is ignored by peers — the member simply moves on, and
  each member attempts a task at most once;
* the job fails only when every member has exhausted its sequence with some
  task still incomplete (``raptor_failure_exact``'s 1-(1-p^F)^K).

Stock (fork-join OpenWhisk) trials are closed-form on-device: one arrival
overhead plus the max of per-task independent service draws.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core.analytics import (flight_fail_rate_batch,
                                  forkjoin_fail_rate_batch, summarize_batch)
from repro.sim.cluster import OverheadModel, lognormal_params
from repro.sim.faults import FaultProfile
from repro.sim.policies import (NO_RECOVERY, RecoveryPolicy, can_fail,
                                chain_transform)
from repro.sim.workloads import (KEYGEN_CV, KEYGEN_MEAN_MS, KEYGEN_OFFSET_MS,
                                 RELIABILITY_CV, RELIABILITY_MEAN_MS)


@dataclasses.dataclass(frozen=True)
class VectorWorkload:
    """Service-time model of one independent-task manifest (vector form)."""
    name: str
    num_tasks: int
    mean_ms: float
    offset_ms: float = 0.0
    dist: str = "exp"              # "exp" | "lognorm"
    cv: float = 1.0
    fail_prob: float = 0.0
    stage_overhead_ms: float = 0.5   # raptor stream hop per attempt
    # fault environment + recovery policy (frozen/hashable -> jit statics
    # and sweep bucket keys).  The open-loop tier models brownouts as a
    # stationary per-invocation snapshot and timeout/retry chains as a
    # draw transform (sim/policies.chain_transform); crash and hedge
    # semantics need wall-clock booking times -> closed-loop tier only
    faults: FaultProfile = None
    recovery: RecoveryPolicy = None


def keygen_vector(fail_prob: float = 0.0, faults: FaultProfile = None,
                  recovery: RecoveryPolicy = None) -> VectorWorkload:
    """ssh-keygen: two entropy-bound tasks, flight of 2 (Tables 7/8)."""
    return VectorWorkload("ssh-keygen", 2, KEYGEN_MEAN_MS, KEYGEN_OFFSET_MS,
                          "lognorm", KEYGEN_CV, fail_prob,
                          faults=faults, recovery=recovery)


def exponential_vector(num_tasks: int = 2, mean_ms: float = 1000.0,
                       fail_prob: float = 0.0, faults: FaultProfile = None,
                       recovery: RecoveryPolicy = None) -> VectorWorkload:
    """Pure exp(mu) tasks — the §4.2.1 theory's exact hypothesis, used to
    show the mutually-independent-exponential prediction emerge with scale."""
    return VectorWorkload(f"exp{num_tasks}", num_tasks, mean_ms, 0.0, "exp",
                          1.0, fail_prob, faults=faults, recovery=recovery)


def reliability_vector(n_tasks: int, fail_prob: float,
                       faults: FaultProfile = None,
                       recovery: RecoveryPolicy = None) -> VectorWorkload:
    """Figure 8's N parallel ~100ms busy-waits with injected task errors."""
    return VectorWorkload(f"busy{n_tasks}", n_tasks, RELIABILITY_MEAN_MS,
                          0.0, "lognorm", RELIABILITY_CV, fail_prob,
                          faults=faults, recovery=recovery)


def _stationary_deg(key, trials: int, num_azs: int, fp: FaultProfile):
    """(trials, A) stationary brownout snapshot; ``correlated`` draws ONE
    process and broadcasts it — the whole cluster degrades together."""
    pi = fp.stationary_degraded
    n = 1 if fp.correlated else num_azs
    d = jax.random.bernoulli(key, pi, (trials, n))
    return jnp.broadcast_to(d, (trials, num_azs)) if fp.correlated else d


# --------------------------------------------------------------------------
# on-device draw primitives (shared with sim/vector_queue.py)
# --------------------------------------------------------------------------

def unit_draws(key, shape, dist: str, cv):
    """Unit-mean service draws: exp(1), lognormal(mean=1, cv), or
    Pareto(mean=1, cv).

    ``cv`` may be traced.  Both vectorized tiers (this open-loop module and
    the closed-loop :mod:`repro.sim.vector_queue`) draw through this one
    helper so the service-time model cannot silently diverge between them.

    "pareto" is the heavy-tail family of the streaming traffic bank:
    classic Pareto(alpha, xm) with alpha = 1 + sqrt(1 + 1/cv^2) (always
    > 2, so mean and variance both exist and hit the requested cv) and
    xm = (alpha - 1)/alpha (unit mean), drawn by inversion
    X = xm * U^(-1/alpha).
    """
    if dist == "exp":
        return jax.random.exponential(key, shape)
    if dist == "pareto":
        alpha = 1.0 + jnp.sqrt(1.0 + 1.0 / (cv * cv))
        xm = (alpha - 1.0) / alpha
        u = jax.random.uniform(key, shape,
                               minval=jnp.finfo(jnp.float32).tiny)
        return xm * u ** (-1.0 / alpha)
    sigma2 = jnp.log1p(cv * cv)
    mu = -sigma2 / 2
    return jnp.exp(mu + jnp.sqrt(sigma2) * jax.random.normal(key, shape))


def _service_draws(key, shape, mean, dist: str, cv):
    return mean * unit_draws(key, shape, dist, cv)


def _overhead_draws(key, shape, med, p90):
    mu, sigma = lognormal_params(med, p90)    # med/p90 are static (Table 6)
    return jnp.exp(mu + sigma * jax.random.normal(key, shape))


# --------------------------------------------------------------------------
# one flight trial: fixed-trip event scan (vmapped over the batch)
# --------------------------------------------------------------------------

def _flight_trial(z_seq, fail_seq, t_join, seq, slat, active=None,
                  num_events: int = None):
    """Replay one flight race.

    Everything per-member is laid out in that member's *sequence order* so
    the scan body is pure one-hot arithmetic — per-trial dynamic gathers
    and scatters cripple the vmapped loop on the CPU backend.

    z_seq:    (F, K) attempt durations, z_seq[m, j] for task seq[m, j]
    fail_seq: (F, K) attempt-error indicators, same layout
    t_join:   (F,)   member join times (arrival control-plane overhead)
    seq:      (F, K) member task orders (cyclic shifts or per-trial perms)
    active:   (F,) bool or None — padding mask for the batched sweeps;
              inactive members never join (fin stays inf, no candidates)
    num_events: tighter exact scan budget when the caller can prove one —
              with ``fail_prob == 0`` every event is the completion of a
              *distinct* task (success broadcasts preempt any peer racing
              the same task before it could complete it again), so K
              events bound the race instead of the conservative F*K
              (tests/test_sim_vector.py pins exactness)
    Returns (response_time, ok).
    """
    F, K = z_seq.shape
    k_arange = jnp.arange(K)
    done0 = jnp.zeros(K, dtype=bool)
    attempted0 = jnp.zeros((F, K), dtype=bool).at[:, 0].set(True)
    if active is not None:
        attempted0 = attempted0 | ~active[:, None]
    cur0 = seq[:, 0]                      # current task id per member
    curfail0 = fail_seq[:, 0]             # whether that attempt will error
    fin0 = t_join + z_seq[:, 0]

    def step(carry, _):
        done, attempted, cur, curfail, fin, finished, ok, t_resp = carry
        active = ~jnp.isinf(fin)
        t = jnp.min(fin)                  # earliest finishing attempt
        e_hot = jnp.arange(F) == jnp.argmin(fin)
        task = jnp.sum(jnp.where(e_hot, cur, 0))
        succ = ~jnp.any(curfail & e_hot)
        done2 = done | ((k_arange == task) & succ)
        complete = jnp.all(done2)
        # the finisher always advances; on success, peers mid-`task` are
        # preempted by the broadcast and advance after the stream half-RTT
        preempted = succ & (cur == task) & active & ~e_hot
        adv = e_hot | preempted
        # next task per member: first in its shifted order that is neither
        # broadcast-complete nor already attempted by this member
        cand = (~done2[seq]) & (~attempted)
        has_next = jnp.any(cand, axis=1)
        j_hot = k_arange[None, :] == jnp.argmax(cand, axis=1)[:, None]
        nxt = jnp.sum(jnp.where(j_hot, seq, 0), axis=1)
        z_next = jnp.sum(jnp.where(j_hot, z_seq, 0.0), axis=1)
        start = jnp.where(e_hot, t, t + slat)
        fin2 = jnp.where(adv,
                         jnp.where(has_next, start + z_next, jnp.inf),
                         fin)
        cur2 = jnp.where(adv, jnp.where(has_next, nxt, -1), cur)
        curfail2 = jnp.where(adv,
                             jnp.any(j_hot & fail_seq, axis=1) & has_next,
                             curfail)
        attempted2 = attempted | (j_hot & (adv & has_next)[:, None])
        # terminal states: every task complete, or every member exhausted
        all_idle = jnp.all(jnp.isinf(fin2))
        terminal = (complete | all_idle) & ~finished
        # no per-element freeze needed past the terminal event: fin is all
        # inf and stays so (starts are priced off t = inf), so post-
        # terminal state drift cannot reach the latched ok/t_resp outputs
        carry2 = (done2, attempted2, cur2, curfail2, fin2,
                  finished | terminal,
                  jnp.where(terminal, complete, ok),
                  jnp.where(terminal, t, t_resp))
        return carry2, None

    carry0 = (done0, attempted0, cur0, curfail0, fin0,
              jnp.array(False), jnp.array(False), jnp.array(jnp.inf))
    # unrolling removes the scan's per-step dispatch overhead — the hot
    # path for small flights is a handful of steps (see BENCH_sim.json)
    steps = int(num_events) if num_events is not None else F * K
    (_, _, _, _, _, finished, ok, t_resp), _ = lax.scan(
        step, carry0, None, length=steps, unroll=min(steps, 8))
    return t_resp, ok


@functools.partial(
    jax.jit,
    static_argnames=("trials", "flight", "num_tasks", "num_azs", "dist",
                     "fail_prob", "oh_med", "oh_p90", "sequences",
                     "faults", "recovery"))
def _raptor_batch(key, *, trials, flight, num_tasks, num_azs, dist,
                  rho, mean, offset, cv, fail_prob, stage_oh, slat,
                  oh_med, oh_p90, sequences="cyclic", faults=None,
                  recovery=None):
    F, K, A = flight, num_tasks, num_azs
    fault_mode = ((faults is not None and faults.enabled)
                  or (recovery is not None and not recovery.is_default))
    pol = recovery if recovery is not None else NO_RECOVERY
    fp = faults if (faults is not None and faults.enabled) else None
    if fault_mode:
        if sequences == "random":
            k_z, k_f, k_o, k_q, k_d, k_e, k_j = jax.random.split(key, 7)
        else:
            k_z, k_f, k_o, k_d, k_e, k_j = jax.random.split(key, 6)
    elif sequences == "random":
        k_z, k_f, k_o, k_q = jax.random.split(key, 4)
    else:
        k_z, k_f, k_o = jax.random.split(key, 3)
    az = jnp.arange(F) % A                        # HA spread placement
    # one fused draw for the AZ-shared S block and the private X block —
    # threefry invocations dominate the batch cost on CPU
    sx = _service_draws(k_z, (trials, A + F, K), mean, dist, cv)
    s, x = sx[:, :A, :], sx[:, A:, :]
    z = rho * s[:, az, :] + (1 - rho) * x + offset + stage_oh
    # fail_prob is static so the p=0 common case folds the whole failure
    # path (and its uniform draw) out of the compiled scan
    if fault_mode:
        # stationary brownout snapshot per (trial, AZ) + the open-loop
        # chain transform: attempt durations inflate while degraded,
        # timeout/retry chains fold into per-attempt (duration, outcome)
        deg = (_stationary_deg(k_d, trials, A, fp) if fp is not None
               else jnp.zeros((trials, A), dtype=bool))
        deg_m = deg[:, az]                        # (trials, F) via placement
        R = pol.max_retries
        u_err = jax.random.uniform(k_e, (trials, F, K, R + 1))
        u_jit = jax.random.uniform(k_j, (trials, F, K, R))
        z, fail = chain_transform(z, u_err, u_jit, deg_m[:, :, None],
                                  policy=pol, faults=fp,
                                  base_fail=fail_prob)
    elif fail_prob == 0.0:
        fail = jnp.zeros((trials, F, K), dtype=bool)
    else:
        fail = jax.random.bernoulli(k_f, fail_prob, (trials, F, K))
    oh = _overhead_draws(k_o, (trials, F + 1), oh_med, oh_p90)
    oh0, ohm = oh[:, 0], oh[:, 1:]
    # member 0 joins at the arrival overhead; later members pay a second
    # control-plane hop (the fork's recursive invocation, §3.3.2)
    t_join = oh0[:, None] + jnp.where(jnp.arange(F) == 0, 0.0, ohm)
    # error-free races complete in exactly K events (see _flight_trial)
    anyfail = (can_fail(fail_prob, fp, pol) if fault_mode
               else fail_prob > 0.0)
    events = K if not anyfail else F * K
    if sequences == "random":
        # fresh uniform order per (trial, member) — the paper-gap probe for
        # the F >> K plateau (cyclic shifts duplicate orders; see ROADMAP)
        perm = jax.vmap(lambda k: jax.random.permutation(k, K))(
            jax.random.split(k_q, trials * F)).reshape(trials, F, K)
        z_seq = jnp.take_along_axis(z, perm, axis=2)
        fail_seq = jnp.take_along_axis(fail, perm, axis=2)
        t_resp, ok = jax.vmap(
            lambda zz, ff, tj, sq: _flight_trial(zz, ff, tj, sq, slat,
                                                 num_events=events))(
                z_seq, fail_seq, t_join, perm)
        return t_resp, ok, fail
    seq = jnp.stack([jnp.roll(jnp.arange(K), -(m % K)) for m in range(F)])
    # permute draws into sequence order once, outside the event scan
    seq_b = jnp.broadcast_to(seq, (trials, F, K))
    z_seq = jnp.take_along_axis(z, seq_b, axis=2)
    fail_seq = jnp.take_along_axis(fail, seq_b, axis=2)
    t_resp, ok = jax.vmap(
        lambda zz, ff, tj: _flight_trial(zz, ff, tj, seq, slat,
                                         num_events=events))(
            z_seq, fail_seq, t_join)
    return t_resp, ok, fail


def _stock_service_mix(key, trials, num_tasks, rho, mean, offset, dist, cv):
    """Stock per-task service times.  Distinct tasks never share an S draw
    (InvocationDraws keys S by (task, az)), but each task's time is still
    the rho-mixture of two i.i.d. draws — same mean, lighter tail than one
    raw draw; the p90/p99 comparisons against the scalar oracle are
    sensitive to this."""
    zz = _service_draws(key, (trials, 2, num_tasks), mean, dist, cv)
    return rho * zz[:, 0] + (1 - rho) * zz[:, 1] + offset


@functools.partial(
    jax.jit, static_argnames=("trials", "num_tasks", "num_azs", "dist",
                              "fail_prob", "oh_med", "oh_p90", "faults",
                              "recovery"))
def _stock_batch(key, *, trials, num_tasks, dist, rho, mean, offset, cv,
                 fail_prob, oh_med, oh_p90, num_azs=3, faults=None,
                 recovery=None):
    fault_mode = ((faults is not None and faults.enabled)
                  or (recovery is not None and not recovery.is_default))
    pol = recovery if recovery is not None else NO_RECOVERY
    fp = faults if (faults is not None and faults.enabled) else None
    if fault_mode:
        k_z, k_f, k_o, k_d, k_e, k_j = jax.random.split(key, 6)
    else:
        k_z, k_f, k_o = jax.random.split(key, 3)
    z = _stock_service_mix(k_z, trials, num_tasks, rho, mean, offset, dist,
                           cv)
    if fault_mode:
        # fork-join tasks spread round-robin over the AZs like the scalar
        # sim's worker pool; each folds its own timeout/retry chain
        deg = (_stationary_deg(k_d, trials, num_azs, fp) if fp is not None
               else jnp.zeros((trials, num_azs), dtype=bool))
        deg_t = deg[:, jnp.arange(num_tasks) % num_azs]
        R = pol.max_retries
        u_err = jax.random.uniform(k_e, (trials, num_tasks, R + 1))
        u_jit = jax.random.uniform(k_j, (trials, num_tasks, R))
        z, fail = chain_transform(z, u_err, u_jit, deg_t, policy=pol,
                                  faults=fp, base_fail=fail_prob)
    elif fail_prob == 0.0:
        fail = jnp.zeros((trials, num_tasks), dtype=bool)
    else:
        fail = jax.random.bernoulli(k_f, fail_prob, (trials, num_tasks))
    oh = _overhead_draws(k_o, (trials,), oh_med, oh_p90)
    t_resp = oh + jnp.max(z, axis=1)              # fork-join: wait for max
    ok = ~jnp.any(fail, axis=1)
    return t_resp, ok, fail


# --------------------------------------------------------------------------
# batched config sweeps: pad-and-mask over flight size, traced rho/AZ/load
# --------------------------------------------------------------------------
# sweep_scale() used to pay a full XLA compile (~1.5s, BENCH_sim.json) per
# (flight, num_azs, rho, load) point because every knob was a static jit
# argument.  Here the knobs are *traced*: flights are padded to a common
# F_pad with inactive members masked out of the event scan, the AZ index is
# a gather from an A_pad-row shared block, and the Table-6 overhead enters
# as (mu, sigma) scalars — so one compilation serves the whole config grid
# via vmap, and adding a point costs milliseconds.

def _raptor_sweep_core(key, flight, num_azs, rho, mean, offset, cv,
                       stage_oh, slat, oh_mu, oh_sigma, *, trials,
                       flight_max, num_tasks, azs_max, dist, fail_prob,
                       faults=None, policy=None):
    F, K, A = flight_max, num_tasks, azs_max
    fault_mode = ((faults is not None and faults.enabled)
                  or (policy is not None and not policy.is_default))
    pol = policy if policy is not None else NO_RECOVERY
    fp = faults if (faults is not None and faults.enabled) else None
    if fault_mode:
        k_z, k_f, k_o, k_d, k_e, k_j = jax.random.split(key, 6)
    else:
        k_z, k_f, k_o = jax.random.split(key, 3)
    active = jnp.arange(F) < flight
    az = jnp.arange(F) % num_azs                  # traced AZ spread
    sx = _service_draws(k_z, (trials, A + F, K), mean, dist, cv)
    s, x = sx[:, :A, :], sx[:, A:, :]
    z = rho * s[:, az, :] + (1 - rho) * x + offset + stage_oh
    if fault_mode:
        deg = (_stationary_deg(k_d, trials, A, fp) if fp is not None
               else jnp.zeros((trials, A), dtype=bool))
        deg_m = deg[:, az]
        R = pol.max_retries
        u_err = jax.random.uniform(k_e, (trials, F, K, R + 1))
        u_jit = jax.random.uniform(k_j, (trials, F, K, R))
        z, fail = chain_transform(z, u_err, u_jit, deg_m[:, :, None],
                                  policy=pol, faults=fp,
                                  base_fail=fail_prob)
    elif fail_prob == 0.0:
        fail = jnp.zeros((trials, F, K), dtype=bool)
    else:
        fail = jax.random.bernoulli(k_f, fail_prob, (trials, F, K))
    oh = jnp.exp(oh_mu + oh_sigma * jax.random.normal(k_o, (trials, F + 1)))
    t_join = oh[:, :1] + jnp.where(jnp.arange(F) == 0, 0.0, oh[:, 1:])
    t_join = jnp.where(active, t_join, jnp.inf)   # padding: never joins
    seq = jnp.stack([jnp.roll(jnp.arange(K), -(m % K)) for m in range(F)])
    seq_b = jnp.broadcast_to(seq, (trials, F, K))
    z_seq = jnp.take_along_axis(z, seq_b, axis=2)
    fail_seq = jnp.take_along_axis(fail, seq_b, axis=2)
    anyfail = (can_fail(fail_prob, fp, pol) if fault_mode
               else fail_prob > 0.0)
    events = K if not anyfail else F * K
    t_resp, ok = jax.vmap(
        lambda zz, ff, tj: _flight_trial(zz, ff, tj, seq, slat, active,
                                         num_events=events))(
            z_seq, fail_seq, t_join)
    # a padded member's error draw never ran, so it must be neutral in the
    # all-attempts-errored reduction (flight_fail_rate_batch ANDs over the
    # flight axis): force it True, i.e. "contributes no rescue attempt"
    fail = fail | ~active[None, :, None]
    return t_resp, ok, fail


def _stock_sweep_core(key, rho, mean, offset, cv, oh_mu, oh_sigma, *,
                      trials, num_tasks, dist, fail_prob, num_azs=3,
                      faults=None, policy=None):
    fault_mode = ((faults is not None and faults.enabled)
                  or (policy is not None and not policy.is_default))
    pol = policy if policy is not None else NO_RECOVERY
    fp = faults if (faults is not None and faults.enabled) else None
    if fault_mode:
        k_z, k_f, k_o, k_d, k_e, k_j = jax.random.split(key, 6)
    else:
        k_z, k_f, k_o = jax.random.split(key, 3)
    z = _stock_service_mix(k_z, trials, num_tasks, rho, mean, offset, dist,
                           cv)
    if fault_mode:
        deg = (_stationary_deg(k_d, trials, num_azs, fp) if fp is not None
               else jnp.zeros((trials, num_azs), dtype=bool))
        deg_t = deg[:, jnp.arange(num_tasks) % num_azs]
        R = pol.max_retries
        u_err = jax.random.uniform(k_e, (trials, num_tasks, R + 1))
        u_jit = jax.random.uniform(k_j, (trials, num_tasks, R))
        z, fail = chain_transform(z, u_err, u_jit, deg_t, policy=pol,
                                  faults=fp, base_fail=fail_prob)
    elif fail_prob == 0.0:
        fail = jnp.zeros((trials, num_tasks), dtype=bool)
    else:
        fail = jax.random.bernoulli(k_f, fail_prob, (trials, num_tasks))
    oh = jnp.exp(oh_mu + oh_sigma * jax.random.normal(k_o, (trials,)))
    t_resp = oh + jnp.max(z, axis=1)
    ok = ~jnp.any(fail, axis=1)
    return t_resp, ok, fail


def pow2_pad(n: int) -> int:
    """Smallest power of two >= n — the pad-and-mask bucket width.

    Shared by every batched sweep that pads a ragged config axis (flight
    size here, event-stream length in the closed-loop tier): padding to the
    next power of two keeps the masked-compute waste under 2x while letting
    all configs in a bucket share one compilation.
    """
    return 1 << max(int(n) - 1, 0).bit_length()


def bucket_by_pad(sizes):
    """Group config indices by their pow2-padded size: {pad: [indices]}.

    One XLA compilation per bucket; a single global pad would make every
    small config pay for the largest one in the sweep.
    """
    buckets = {}
    for i, n in enumerate(sizes):
        buckets.setdefault(pow2_pad(n), []).append(i)
    return buckets


def sweep_pairs(wl: "VectorWorkload", configs, *, trials: int = 20_000,
                seed: int = 0, devices=None):
    """Run many (flight, num_azs, rho, load) points in ONE compile each for
    the raptor and stock paths.

    ``configs`` is a sequence of dicts with keys ``flight``, ``num_azs``,
    and optional ``rho`` (default 0.95) and ``load`` (default "medium").
    Returns one dict per config with stock/raptor summaries + mean ratio.

    A thin plan over the device-sharded sweep driver: the bucketing and
    pad-and-mask plumbing live in :mod:`repro.sim.sweeps`, and the config
    axis shards over ``devices`` (default: every jax device) with results
    bit-identical to the single-device run.
    """
    from repro.sim.sweeps import open_loop_pair_plan
    return open_loop_pair_plan(wl, configs, trials=trials,
                               seed=seed).run(devices=devices)


# --------------------------------------------------------------------------
# public driver
# --------------------------------------------------------------------------

@dataclasses.dataclass
class VectorResult:
    response_ms: jnp.ndarray     # (trials,)
    ok: jnp.ndarray              # (trials,) bool
    fail_draws: jnp.ndarray      # raptor (trials,F,K) / stock (trials,K)
    raptor: bool

    @property
    def trials(self) -> int:
        return int(self.response_ms.shape[0])

    def fail_rate(self) -> float:
        return float(1.0 - jnp.mean(self.ok))

    def theory_fail_rate(self) -> float:
        """Failure rate recomputed from the raw error draws on-device —
        cross-checks the event replay against the order-statistics form."""
        if self.raptor:
            return float(flight_fail_rate_batch(self.fail_draws))
        return float(forkjoin_fail_rate_batch(self.fail_draws))

    def summary(self) -> dict:
        """Delay summary conditioned on SUCCESS, failure accounting kept
        alongside.

        A failed job's "response" is its failure-*detection* time (every
        member exhausted), not a delay a client would see — mixing those
        into the percentiles biases the raptor summaries whenever
        ``fail_prob > 0``.  ``n`` counts the successful jobs summarized;
        ``n_failed`` and ``fail_rate`` carry the failure accounting.
        """
        ok = np.asarray(self.ok, dtype=bool)
        resp = np.asarray(self.response_ms)[ok]
        if resp.size:
            s = {k: (int(v) if k == "n" else float(v))
                 for k, v in summarize_batch(resp).items()}
        else:
            nan = float("nan")
            s = dict(mean=nan, median=nan, p90=nan, p99=nan, scv=nan, n=0)
        s["fail_rate"] = self.fail_rate()
        s["n_failed"] = int(ok.size - ok.sum())
        return s


class VectorFlightSim:
    """Batched Monte-Carlo of one (workload, deployment) configuration.

    Deployment knobs mirror :class:`repro.sim.cluster.Cluster`: AZ count
    (members are spread round-robin, the HA placement), correlation ``rho``,
    and the Table-6 control-plane overhead regime per (ha, load).
    """

    def __init__(self, wl: VectorWorkload, *, num_azs: int = 3,
                 flight: int = 2, rho: float = 0.95, load: str = "medium",
                 stream_latency_ms: float = 0.5, seed: int = 0,
                 sequences: str = "cyclic"):
        if sequences not in ("cyclic", "random"):
            raise ValueError(f"unknown sequences mode {sequences!r}")
        self.wl = wl
        self.num_azs = int(num_azs)
        self.flight = int(flight)
        self.rho = float(rho)
        self.load = load
        self.slat = float(stream_latency_ms)
        self.seed = int(seed)
        self.sequences = sequences
        ha = self.num_azs > 1
        self.oh_med, self.oh_p90 = OverheadModel.TABLE[(ha, load)]

    def _key(self, raptor: bool):
        return jax.random.PRNGKey(self.seed * 2 + (1 if raptor else 0))

    def run(self, trials: int = 10_000, *, raptor: bool = True) -> VectorResult:
        wl = self.wl
        if raptor:
            t, ok, fail = _raptor_batch(
                self._key(True), trials=int(trials), flight=self.flight,
                num_tasks=wl.num_tasks, num_azs=self.num_azs, dist=wl.dist,
                rho=self.rho, mean=wl.mean_ms, offset=wl.offset_ms,
                cv=wl.cv, fail_prob=wl.fail_prob,
                stage_oh=wl.stage_overhead_ms, slat=self.slat,
                oh_med=self.oh_med, oh_p90=self.oh_p90,
                sequences=self.sequences, faults=wl.faults,
                recovery=wl.recovery)
        else:
            t, ok, fail = _stock_batch(
                self._key(False), trials=int(trials),
                num_tasks=wl.num_tasks, dist=wl.dist, rho=self.rho,
                mean=wl.mean_ms, offset=wl.offset_ms, cv=wl.cv,
                fail_prob=wl.fail_prob,
                oh_med=self.oh_med, oh_p90=self.oh_p90,
                num_azs=self.num_azs, faults=wl.faults,
                recovery=wl.recovery)
        return VectorResult(t, ok, fail, raptor)

    def run_pair(self, trials: int = 10_000) -> Dict[str, dict]:
        """Stock + Raptor summaries and their mean ratio (Table-7 shape).

        The ratio divides the success-conditioned means (see
        :meth:`VectorResult.summary`), so injected failures perturb
        ``fail_rate``/``n_failed`` but never the delay comparison.
        """
        stock = self.run(trials, raptor=False)
        rap = self.run(trials, raptor=True)
        out = {"stock": stock.summary(), "raptor": rap.summary()}
        out["mean_ratio"] = out["raptor"]["mean"] / out["stock"]["mean"]
        return out
