"""The paper's three evaluation workloads as service-time models (§4.2).

Calibration: constants are fit so the STOCK OpenWhisk path reproduces the
"w/o Raptor" column of Table 7 on the HA 3-AZ cluster at moderate load; the
Raptor path is then *prediction*, not fit — its match to the "w/ Raptor"
column (and to 2*E[min]/E[max] = 2/3) is the reproduction result.
"""
from __future__ import annotations

from repro.sim.cluster import Cluster
from repro.sim.faults import FaultProfile
from repro.sim.flights import SimWorkload
from repro.sim.policies import RecoveryPolicy

# load levels as utilisation targets of the flight variant's capacity —
# shared by the scalar experiment drivers and the vectorized queue engine
UTIL = {"low": 0.18, "medium": 0.45, "high": 0.75}


def arrival_rate_hz(work_est_ws: float, num_workers: int, load: str) -> float:
    """Poisson arrival rate hitting the UTIL[load] utilisation target."""
    if load not in UTIL:
        raise ValueError(
            f"unknown load {load!r}: expected one of {sorted(UTIL)} "
            f"(utilisation targets {UTIL})")
    if work_est_ws <= 0.0:
        raise ValueError(f"work_est_ws must be positive, got {work_est_ws}")
    if num_workers <= 0:
        raise ValueError(f"num_workers must be positive, got {num_workers}")
    return UTIL[load] * num_workers / work_est_ws

# ---- ssh-keygen: two entropy-bound tasks, flight of 2 (Table 8) ----------
# lognormal(mean 875 ms, cv 1.45) + 40 ms offset: fit to the STOCK column of
# Table 7 (gives 1399/936/2885 vs paper 1335/939/2887); heavy tail matches
# the paper's med/mean = 0.70, p90/mean = 2.16 better than an exponential.
KEYGEN_MEAN_MS = 875.0
KEYGEN_CV = 1.45
KEYGEN_OFFSET_MS = 40.0


def keygen_workload(fail_prob: float = 0.0,
                    faults: FaultProfile = None,
                    recovery: RecoveryPolicy = None) -> SimWorkload:
    return SimWorkload(
        name="ssh-keygen",
        tasks=["keygen_a", "keygen_b"],
        deps={"keygen_a": (), "keygen_b": ()},
        concurrency=2,
        make_draws=lambda cl: cl.draws(KEYGEN_MEAN_MS, KEYGEN_OFFSET_MS,
                                       "lognorm", cv=KEYGEN_CV),
        stock_stage_overhead=0.0,
        fail_prob=fail_prob,
        work_est_ws=1.9,
        faults=faults,
        recovery=recovery,
    )


# ---- word count: serverless map-reduce (AWS-style ad-hoc pipeline) --------
WC_SPLIT_MS = 300.0
WC_MAP_MS = 700.0
WC_REDUCE_MS = 420.0
WC_STORAGE_HOP_MS = 800.0      # S3/GCS round-trip on the stock control path


def wordcount_workload(fail_prob: float = 0.0,
                       faults: FaultProfile = None,
                       recovery: RecoveryPolicy = None) -> SimWorkload:
    means = {"split": WC_SPLIT_MS, "reduce": WC_REDUCE_MS}
    means.update({f"map{i}": WC_MAP_MS for i in range(4)})

    def make_draws(cl: Cluster):
        base = cl.draws(1.0, 0.0, "exp")
        draw0 = base.draw

        def draw(task, worker):
            return draw0(task, worker) * means[task]
        base.draw = draw
        return base

    deps = {"split": (), "reduce": tuple(f"map{i}" for i in range(4))}
    deps.update({f"map{i}": ("split",) for i in range(4)})
    return SimWorkload(
        name="wordcount",
        tasks=["split", "map0", "map1", "map2", "map3", "reduce"],
        deps=deps,
        concurrency=2,
        make_draws=make_draws,
        stock_stage_overhead=WC_STORAGE_HOP_MS,
        fail_prob=fail_prob,
        work_est_ws=4.2,
        faults=faults,
        recovery=recovery,
    )


# ---- thumbnails: download stage + 4 resize tasks, flight of 4 -------------
# Paper §4.2.2: the source image is downloaded, then four thumbnails of
# different sizes are generated and uploaded.  STOCK functions are
# self-contained (each re-downloads the source: task = download + resize);
# Raptor's manifest factors the download out and the state-sharing stream
# hands the bytes to every member — the data-path short-circuit that gives
# the paper's "muted but still positive" ~11% win on this deterministic
# workload.
THUMB_DOWNLOAD_MS = 480.0
THUMB_RESIZE_MS = 800.0
THUMB_CV = 0.22


def thumbnail_workload(fail_prob: float = 0.0,
                       faults: FaultProfile = None,
                       recovery: RecoveryPolicy = None) -> SimWorkload:
    means = {"download": THUMB_DOWNLOAD_MS}
    means.update({f"thumb{i}": THUMB_RESIZE_MS for i in range(4)})

    def make_draws(cl: Cluster):
        base = cl.draws(1.0, 0.0, "lognorm", cv=THUMB_CV)
        draw0 = base.draw

        def draw(task, worker):
            t = draw0(task, worker) * means[task]
            if task.startswith("thumb") and not getattr(base, "raptor", False):
                # stock path: self-contained function re-downloads source
                t += draw0(task + "_dl", worker) * THUMB_DOWNLOAD_MS
            return t
        base.draw = draw
        return base

    deps = {"download": ()}
    deps.update({f"thumb{i}": ("download",) for i in range(4)})
    thumbs = [f"thumb{i}" for i in range(4)]
    return SimWorkload(
        name="thumbnail",
        tasks=["download"] + thumbs,
        deps=deps,
        concurrency=4,
        make_draws=make_draws,
        stock_stage_overhead=0.0,
        fail_prob=fail_prob,
        work_est_ws=5.6,
        faults=faults,
        recovery=recovery,
        stock_tasks=thumbs,                 # stock fns are self-contained
        stock_deps={t: () for t in thumbs},
    )


# ---- reliability probe: N parallel 100ms busy-waits (Figure 8) ------------
RELIABILITY_MEAN_MS = 100.0
RELIABILITY_CV = 0.05


def reliability_workload(n_tasks: int, fail_prob: float,
                         faults: FaultProfile = None,
                         recovery: RecoveryPolicy = None) -> SimWorkload:
    tasks = [f"busy{i}" for i in range(n_tasks)]
    return SimWorkload(
        name=f"busy{n_tasks}",
        tasks=tasks,
        deps={t: () for t in tasks},
        concurrency=n_tasks,
        make_draws=lambda cl: cl.draws(RELIABILITY_MEAN_MS, 0.0, "lognorm",
                                       cv=RELIABILITY_CV),
        fail_prob=fail_prob,
        work_est_ws=0.1 * n_tasks * 2,
        faults=faults,
        recovery=recovery,
    )
