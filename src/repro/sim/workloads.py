"""The evaluation workload bank: declarative specs + service-time models.

Every workload is ONE compiled :class:`repro.core.workflow.WorkflowGraph`
(the manifest compiler's IR) consumed by all engines — the scalar oracle
(`sim/flights.py`), the vectorized closed-loop engines
(`sim/vector_queue.py`), and streaming/sweeps.  The graph factories here
are that single source of truth; the per-engine workload wrappers
(:class:`SimWorkload` here + ``QueueWorkload`` in `sim/vector_queue.py`)
bind the same graphs to each engine's service-draw machinery.

Calibration: constants are fit so the STOCK OpenWhisk path reproduces the
"w/o Raptor" column of Table 7 on the HA 3-AZ cluster at moderate load; the
Raptor path is then *prediction*, not fit — its match to the "w/ Raptor"
column (and to 2*E[min]/E[max] = 2/3) is the reproduction result.

Beyond the paper's three workloads, the bank seeds deeper graphs the
hand-rolled manifests never exercised (EXPERIMENTS.md §manifests):

* :func:`etl_graph` — a job -> stage -> task ETL pipeline: ingest, a
  ``validate`` guard whose outcome routes poison jobs down a quarantine
  branch (data-dependent :func:`repro.core.workflow.conditional`), a
  wide parameterized transform fan-out, and a commit joining both arms;
* :func:`mapreduce_graph` — ranked map fan-out, an explicit
  :func:`repro.core.workflow.barrier` sync, a ranked reduce stage, and
  a publish sink.
"""
from __future__ import annotations

from typing import Optional

from repro.core.workflow import (WorkflowGraph, barrier, branch, chain,
                                 compile_spec, conditional, fanout, task)
from repro.sim.cluster import Cluster
from repro.sim.faults import FaultProfile
from repro.sim.flights import SimWorkload
from repro.sim.policies import RecoveryPolicy

# load levels as utilisation targets of the flight variant's capacity —
# shared by the scalar experiment drivers and the vectorized queue engine
UTIL = {"low": 0.18, "medium": 0.45, "high": 0.75}


def arrival_rate_hz(work_est_ws: float, num_workers: int, load: str) -> float:
    """Poisson arrival rate hitting the UTIL[load] utilisation target."""
    if load not in UTIL:
        raise ValueError(
            f"unknown load {load!r}: expected one of {sorted(UTIL)} "
            f"(utilisation targets {UTIL})")
    if work_est_ws <= 0.0:
        raise ValueError(f"work_est_ws must be positive, got {work_est_ws}")
    if num_workers <= 0:
        raise ValueError(f"num_workers must be positive, got {num_workers}")
    return UTIL[load] * num_workers / work_est_ws

# ---- ssh-keygen: two entropy-bound tasks, flight of 2 (Table 8) ----------
# lognormal(mean 875 ms, cv 1.45) + 40 ms offset: fit to the STOCK column of
# Table 7 (gives 1399/936/2885 vs paper 1335/939/2887); heavy tail matches
# the paper's med/mean = 0.70, p90/mean = 2.16 better than an exponential.
KEYGEN_MEAN_MS = 875.0
KEYGEN_CV = 1.45
KEYGEN_OFFSET_MS = 40.0


def keygen_graph() -> WorkflowGraph:
    return compile_spec(branch(task("keygen_a", KEYGEN_MEAN_MS),
                               task("keygen_b", KEYGEN_MEAN_MS)),
                        name="ssh-keygen")


def keygen_workload(fail_prob: float = 0.0,
                    faults: Optional[FaultProfile] = None,
                    recovery: Optional[RecoveryPolicy] = None) -> SimWorkload:
    return SimWorkload(
        graph=keygen_graph(),
        concurrency=2,
        make_draws=lambda cl: cl.draws(KEYGEN_MEAN_MS, KEYGEN_OFFSET_MS,
                                       "lognorm", cv=KEYGEN_CV),
        stock_stage_overhead=0.0,
        fail_prob=fail_prob,
        work_est_ws=1.9,
        faults=faults,
        recovery=recovery,
    )


def _graph_draws(graph: WorkflowGraph, cl: Cluster, dist: str,
                 cv: float = 1.0):
    """Unit draws scaled by the graph's per-task mean bindings — the
    scalar engines' view of the IR's service model."""
    means = dict(zip(graph.tasks, graph.means))
    base = cl.draws(1.0, 0.0, dist, cv=cv)
    draw0 = base.draw

    def draw(t, worker):
        return draw0(t, worker) * means[t]
    base.draw = draw
    return base


# ---- word count: serverless map-reduce (AWS-style ad-hoc pipeline) --------
WC_SPLIT_MS = 300.0
WC_MAP_MS = 700.0
WC_REDUCE_MS = 420.0
WC_STORAGE_HOP_MS = 800.0      # S3/GCS round-trip on the stock control path


def wordcount_graph() -> WorkflowGraph:
    return compile_spec(chain(task("split", WC_SPLIT_MS),
                              fanout(task("map", WC_MAP_MS), 4),
                              task("reduce", WC_REDUCE_MS)),
                        name="wordcount")


def wordcount_workload(fail_prob: float = 0.0,
                       faults: Optional[FaultProfile] = None,
                       recovery: Optional[RecoveryPolicy] = None
                       ) -> SimWorkload:
    g = wordcount_graph()
    return SimWorkload(
        graph=g,
        concurrency=2,
        make_draws=lambda cl: _graph_draws(g, cl, "exp"),
        stock_stage_overhead=WC_STORAGE_HOP_MS,
        fail_prob=fail_prob,
        work_est_ws=4.2,
        faults=faults,
        recovery=recovery,
    )


# ---- thumbnails: download stage + 4 resize tasks, flight of 4 -------------
# Paper §4.2.2: the source image is downloaded, then four thumbnails of
# different sizes are generated and uploaded.  STOCK functions are
# self-contained (each re-downloads the source: task = download + resize);
# Raptor's manifest factors the download out and the state-sharing stream
# hands the bytes to every member — the data-path short-circuit that gives
# the paper's "muted but still positive" ~11% win on this deterministic
# workload.
THUMB_DOWNLOAD_MS = 480.0
THUMB_RESIZE_MS = 800.0
THUMB_CV = 0.22


def thumbnail_graph() -> WorkflowGraph:
    return compile_spec(chain(task("download", THUMB_DOWNLOAD_MS),
                              fanout(task("thumb", THUMB_RESIZE_MS), 4)),
                        name="thumbnail")


def thumbnail_stock_graph() -> WorkflowGraph:
    """Stock functions are self-contained: four dep-free resize tasks
    (each pays the re-download as a second service component)."""
    return compile_spec(fanout(task("thumb", THUMB_RESIZE_MS), 4),
                        name="thumbnail")


def thumbnail_workload(fail_prob: float = 0.0,
                       faults: Optional[FaultProfile] = None,
                       recovery: Optional[RecoveryPolicy] = None
                       ) -> SimWorkload:
    g = thumbnail_graph()
    means = dict(zip(g.tasks, g.means))

    def make_draws(cl: Cluster):
        base = cl.draws(1.0, 0.0, "lognorm", cv=THUMB_CV)
        draw0 = base.draw

        def draw(t, worker):
            svc = draw0(t, worker) * means[t]
            if t.startswith("thumb") and not getattr(base, "raptor", False):
                # stock path: self-contained function re-downloads source
                svc += draw0(t + "_dl", worker) * THUMB_DOWNLOAD_MS
            return svc
        base.draw = draw
        return base

    return SimWorkload(
        graph=g,
        concurrency=4,
        make_draws=make_draws,
        stock_stage_overhead=0.0,
        fail_prob=fail_prob,
        work_est_ws=5.6,
        faults=faults,
        recovery=recovery,
        stock=thumbnail_stock_graph(),      # stock fns are self-contained
    )


# ---- reliability probe: N parallel 100ms busy-waits (Figure 8) ------------
RELIABILITY_MEAN_MS = 100.0
RELIABILITY_CV = 0.05


def reliability_graph(n_tasks: int) -> WorkflowGraph:
    return compile_spec(fanout(task("busy", RELIABILITY_MEAN_MS), n_tasks),
                        name=f"busy{n_tasks}")


def reliability_workload(n_tasks: int, fail_prob: float,
                         faults: Optional[FaultProfile] = None,
                         recovery: Optional[RecoveryPolicy] = None
                         ) -> SimWorkload:
    return SimWorkload(
        graph=reliability_graph(n_tasks),
        concurrency=n_tasks,
        make_draws=lambda cl: cl.draws(RELIABILITY_MEAN_MS, 0.0, "lognorm",
                                       cv=RELIABILITY_CV),
        fail_prob=fail_prob,
        work_est_ws=0.1 * n_tasks * 2,
        faults=faults,
        recovery=recovery,
    )


# ---- workload bank: deeper graphs through the manifest compiler -----------
# ETL pipeline (job -> stage -> task): ingest, a validation guard whose
# OUTCOME routes the job — clean jobs fan out over `rank` transforms and
# load, poison jobs detour to quarantine — and a commit that joins both
# arms.  `fail_prob` doubles as the poison rate: the guard's deciding
# attempt fails with that probability and the conditional selects the
# quarantine branch (plus ordinary per-task error/retry dynamics on the
# rest of the graph).
ETL_INGEST_MS = 220.0
ETL_VALIDATE_MS = 140.0
ETL_XFORM_MS = 420.0
ETL_LOAD_MS = 260.0
ETL_QUARANTINE_MS = 300.0
ETL_COMMIT_MS = 180.0


def etl_graph(rank: int = 6) -> WorkflowGraph:
    spec = chain(
        task("ingest", ETL_INGEST_MS),
        conditional(
            task("validate", ETL_VALIDATE_MS),
            then=chain(fanout(task("xform", ETL_XFORM_MS), rank),
                       task("load", ETL_LOAD_MS)),
            orelse=task("quarantine", ETL_QUARANTINE_MS)),
        task("commit", ETL_COMMIT_MS))
    return compile_spec(spec, name=f"etl{rank}")


def _etl_work_ws(rank: int) -> float:
    happy = (ETL_INGEST_MS + ETL_VALIDATE_MS + rank * ETL_XFORM_MS
             + ETL_LOAD_MS + ETL_COMMIT_MS)
    return happy / 1000.0


def etl_workload(rank: int = 6, fail_prob: float = 0.08,
                 faults: Optional[FaultProfile] = None,
                 recovery: Optional[RecoveryPolicy] = None) -> SimWorkload:
    g = etl_graph(rank)
    return SimWorkload(
        graph=g,
        concurrency=3,
        make_draws=lambda cl: _graph_draws(g, cl, "exp"),
        stock_stage_overhead=WC_STORAGE_HOP_MS,
        fail_prob=fail_prob,
        work_est_ws=_etl_work_ws(rank),
        faults=faults,
        recovery=recovery,
    )


# Ranked map-reduce with a sync barrier: scatter -> rank maps -> BARRIER ->
# `reducers` reduces (each joined on every map by the barrier) -> publish.
MR_SCATTER_MS = 250.0
MR_MAP_MS = 600.0
MR_REDUCE_MS = 480.0
MR_PUBLISH_MS = 150.0


def mapreduce_graph(rank: int = 4, reducers: int = 2) -> WorkflowGraph:
    spec = chain(
        task("scatter", MR_SCATTER_MS),
        fanout(task("map", MR_MAP_MS), rank),
        barrier(),
        fanout(task("reduce", MR_REDUCE_MS), reducers),
        task("publish", MR_PUBLISH_MS))
    return compile_spec(spec, name=f"mapreduce{rank}x{reducers}")


def _mapreduce_work_ws(rank: int, reducers: int) -> float:
    return (MR_SCATTER_MS + rank * MR_MAP_MS + reducers * MR_REDUCE_MS
            + MR_PUBLISH_MS) / 1000.0


def mapreduce_workload(rank: int = 4, reducers: int = 2,
                       fail_prob: float = 0.0,
                       faults: Optional[FaultProfile] = None,
                       recovery: Optional[RecoveryPolicy] = None
                       ) -> SimWorkload:
    g = mapreduce_graph(rank, reducers)
    return SimWorkload(
        graph=g,
        concurrency=3,
        make_draws=lambda cl: _graph_draws(g, cl, "exp"),
        stock_stage_overhead=WC_STORAGE_HOP_MS,
        fail_prob=fail_prob,
        work_est_ws=_mapreduce_work_ws(rank, reducers),
        faults=faults,
        recovery=recovery,
    )
