"""Cluster model: workers across availability zones, control-plane overhead,
and AZ-correlated service times (the paper's central mechanism).

Correlation model (DESIGN.md §2, paper §4.2.1): the execution time of an
entropy-bound task ``t`` on worker ``w`` within one invocation is

    Z = rho * S(t, az(w)) + (1 - rho) * X(t, w)

with S and X i.i.d. exponential(mu), S shared by every worker in the same
AZ.  Replicas co-located in one AZ therefore see nearly identical delays
(rho -> 1: speculation is useless), while replicas spread across AZs draw
independent S and are nearly independent (the full E[min] win).  A
1-AZ/5-worker deployment forces same-AZ placement; the 3-AZ/15-worker HA
deployment spreads flights across AZs — reproducing the paper's scale
effect without any other change.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np


def lognormal_params(med: float, p90: float) -> tuple:
    """(mu, sigma) of the lognormal with the given median and p90 — shared
    by the scalar OverheadModel and the vectorized sim so the Table-6
    parameterization cannot silently diverge between the two."""
    mu = float(np.log(med))
    sigma = max((float(np.log(p90)) - mu) / 1.2816, 0.05)
    return mu, sigma


@dataclasses.dataclass
class OverheadModel:
    """Control-plane latency (paper Table 6) as a lognormal per (ha, load)."""
    TABLE = {
        (True, "low"): (8.0, 14.0), (True, "medium"): (9.0, 16.0),
        (True, "high"): (9.0, 15.0),
        (False, "low"): (6.0, 12.0), (False, "medium"): (6.0, 9.0),
        (False, "high"): (7.0, 15.0),
    }

    def sample(self, rng, ha: bool, load: str, n: int = 1) -> np.ndarray:
        mu, sigma = lognormal_params(*self.TABLE[(ha, load)])
        return np.exp(rng.normal(mu, sigma, size=n))


class InvocationDraws:
    """Correlated service-time draws for ONE invocation of a manifest."""

    def __init__(self, cluster: "Cluster", mean_ms: float, offset_ms: float,
                 dist: str = "exp", cv: float = 1.0):
        self.cl = cluster
        self.mean = mean_ms
        self.offset = offset_ms
        self.dist = dist
        self.cv = cv
        self._shared: Dict[tuple, float] = {}

    def _base_draw(self) -> float:
        rng = self.cl.rng
        if self.dist == "exp":
            return float(rng.exponential(self.mean))
        # lognormal with given cv (thumbnail-style deterministic-ish tasks)
        sigma2 = np.log(1 + self.cv ** 2)
        mu = np.log(self.mean) - sigma2 / 2
        return float(np.exp(rng.normal(mu, np.sqrt(sigma2))))

    def draw(self, task: str, worker: int) -> float:
        az = int(self.cl.az_of[worker])
        key = (task, az)
        if key not in self._shared:
            self._shared[key] = self._base_draw()
        s = self._shared[key]
        x = self._base_draw()
        rho = self.cl.rho
        return rho * s + (1 - rho) * x + self.offset


@dataclasses.dataclass
class Cluster:
    num_workers: int = 15
    num_azs: int = 3
    rho: float = 0.95          # AZ-shared fraction of service time
    seed: int = 0

    def __post_init__(self):
        self.rng = np.random.default_rng(self.seed)
        self.az_of = np.arange(self.num_workers) % self.num_azs
        self.overhead = OverheadModel()

    @property
    def ha(self) -> bool:
        return self.num_azs > 1

    def sample_overhead(self, load: str, n: int = 1) -> np.ndarray:
        return self.overhead.sample(self.rng, self.ha, load, n)

    def draws(self, mean_ms: float, offset_ms: float = 0.0, dist: str = "exp",
              cv: float = 1.0) -> InvocationDraws:
        return InvocationDraws(self, mean_ms, offset_ms, dist, cv)

    def place_flight(self, size: int, busy: Optional[set] = None) -> List[int]:
        """HA placement: spread flight members over AZs first."""
        busy = busy or set()
        free = [w for w in range(self.num_workers) if w not in busy]
        by_az: Dict[int, List[int]] = {}
        for w in free:
            by_az.setdefault(int(self.az_of[w]), []).append(w)
        for ws in by_az.values():
            self.rng.shuffle(ws)
        azs = list(by_az)
        self.rng.shuffle(azs)
        picked: List[int] = []
        i = 0
        while len(picked) < size and any(by_az.values()):
            az = azs[i % len(azs)]
            if by_az[az]:
                picked.append(by_az[az].pop())
            i += 1
        return picked
