"""Declarative workflow specs compiled to one IR for every engine.

The paper's action manifests (§3.3.1, Table 1) are a general DAG
abstraction, but hand-transcribing each workflow per engine (scalar
oracle dep-dicts, vector deps tuples, streaming static keys) caps the
reproduction at two graphs.  This module is the single frontend:

* **spec combinators** — :func:`task`, :func:`chain`, :func:`fanout`
  (parameterized rank), :func:`branch`, :func:`barrier`, and
  data-dependent :func:`conditional` on task outcomes — compose an
  immutable spec tree (the taxonomy of Ripple's declarative frontend
  and Wukong's DAG model, PAPERS.md);
* :func:`compile_spec` lowers any spec to a :class:`WorkflowGraph` —
  the one IR every engine consumes: per-member dependency masks
  (:meth:`WorkflowGraph.dep_mask`, :meth:`WorkflowGraph
  .member_sequences`), level schedules (:meth:`WorkflowGraph.levels`),
  conditional select masks (:attr:`WorkflowGraph.cond_static`), and
  per-task service-model bindings (:attr:`WorkflowGraph.means`).

``WorkflowGraph`` is frozen and hashable, so the compiled graph IS the
static cache key of the jitted trial builders and the sweep bucket
cores (`sim/vector_queue.py`, `sim/sweeps.py`) — content-equal graphs
share compiled executables, and :attr:`WorkflowGraph.manifest_hash`
names the compiled content for bench records and bucket bookkeeping.

Chain linking rule: consecutive fragments connect **lane-wise** when
the upstream sink count equals the downstream source count (ranked
fan-out lanes stay parallel), else **all-to-all** (a fan-in join);
:func:`barrier` forces the all-to-all collapse regardless of rank —
the explicit synchronization point.

Conditional semantics (mask-select on outcomes): the guard task's
FIRST finished attempt decides the branch regardless of its
success/failure — failure is a *routing outcome*, not a job error.
Every task in the not-taken branch is cancelled at that instant
(marked complete with zero service; its dependents become runnable).
All gated tasks structurally depend on the guard, so no executor can
be mid-attempt on a task when it is cancelled.  Nested conditionals
are rejected at compile time (one (guard, sense) select slot per
task).  The stock baseline has no data-dependent short-circuiting, so
stock engines consume :meth:`WorkflowGraph.flatten` — both branches
run unconditionally.
"""
from __future__ import annotations

import dataclasses
import functools
import hashlib
from typing import Dict, Optional, Tuple

import numpy as np


# --------------------------------------------------------------------------
# spec combinators (an immutable AST)
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Task:
    """One unit of work: a name and its mean service time binding."""
    name: str
    mean_ms: float = 1.0


@dataclasses.dataclass(frozen=True)
class Chain:
    parts: Tuple


@dataclasses.dataclass(frozen=True)
class Fanout:
    proto: object
    rank: int


@dataclasses.dataclass(frozen=True)
class Branch:
    parts: Tuple


@dataclasses.dataclass(frozen=True)
class Barrier:
    pass


@dataclasses.dataclass(frozen=True)
class Conditional:
    guard: Task
    then: object
    orelse: Optional[object] = None


def task(name: str, mean_ms: float = 1.0) -> Task:
    return Task(str(name), float(mean_ms))


def chain(*parts) -> Chain:
    """Sequential composition; lane-wise when ranks match, else fan-in."""
    if not parts:
        raise ValueError("chain needs at least one part")
    return Chain(tuple(parts))


def fanout(proto, rank: int) -> Fanout:
    """``rank`` replicas of ``proto``, each task name suffixed by its
    lane index (``task('map')`` -> ``map0..map{rank-1}``)."""
    if rank < 1:
        raise ValueError(f"fanout rank must be >= 1, got {rank}")
    return Fanout(proto, int(rank))


def branch(*parts) -> Branch:
    """Independent parallel composition (no cross-part edges)."""
    if not parts:
        raise ValueError("branch needs at least one part")
    return Branch(tuple(parts))


def barrier() -> Barrier:
    """Explicit sync point inside a chain: forces the next link to join
    all-to-all even when lane counts match."""
    return Barrier()


def conditional(guard: Task, then, orelse=None) -> Conditional:
    """Data-dependent branch on ``guard``'s outcome: ``then`` runs when
    the guard's deciding attempt succeeds, ``orelse`` when it fails; the
    other branch is cancelled (mask-select, see module docstring)."""
    if not isinstance(guard, Task):
        raise ValueError("conditional guard must be a single task()")
    return Conditional(guard, then, orelse)


# --------------------------------------------------------------------------
# compilation: spec tree -> fragment -> WorkflowGraph
# --------------------------------------------------------------------------

@dataclasses.dataclass
class _Fragment:
    """Partially-linked subgraph: ordered rows + open frontier lists."""
    rows: list          # [name, mean, deps(list), cond((guard, sense)|None)]
    sources: list       # task names awaiting upstream edges
    sinks: list         # task names downstream fragments attach to


def _suffixed(frag: _Fragment, i: int) -> _Fragment:
    ren = {r[0]: f"{r[0]}{i}" for r in frag.rows}
    rows = [[ren[n], m, [ren[d] for d in ds],
             None if c is None else (ren[c[0]], c[1])]
            for n, m, ds, c in frag.rows]
    return _Fragment(rows, [ren[s] for s in frag.sources],
                     [ren[s] for s in frag.sinks])


def _concat(frags) -> _Fragment:
    out = _Fragment([], [], [])
    for f in frags:
        out.rows += f.rows
        out.sources += f.sources
        out.sinks += f.sinks
    return out


def _link(up: _Fragment, down: _Fragment, force_join: bool) -> None:
    """Wire ``down.sources`` onto ``up.sinks``: lane-wise on matching
    rank (unless a barrier forced the join), else all-to-all."""
    by_name = {r[0]: r for r in down.rows}
    if not force_join and len(up.sinks) == len(down.sources):
        for s, d in zip(up.sinks, down.sources):
            by_name[d][2].append(s)
    else:
        for d in down.sources:
            by_name[d][2].extend(up.sinks)


def _build(node) -> _Fragment:
    if isinstance(node, Task):
        return _Fragment([[node.name, node.mean_ms, [], None]],
                         [node.name], [node.name])
    if isinstance(node, Fanout):
        return _concat(_suffixed(_build(node.proto), i)
                       for i in range(node.rank))
    if isinstance(node, Branch):
        return _concat(_build(p) for p in node.parts)
    if isinstance(node, Chain):
        frags, pending = [], False
        for part in node.parts:
            if isinstance(part, Barrier):
                if not frags:
                    raise ValueError("barrier cannot open a chain")
                pending = True
                continue
            frag = _build(part)
            if frags:
                _link(frags[-1], frag, pending)
            frags.append(frag)
            pending = False
        if pending:
            raise ValueError("barrier cannot close a chain")
        if not frags:
            raise ValueError("chain needs at least one non-barrier part")
        out = _concat(frags)
        out.sources = frags[0].sources
        out.sinks = frags[-1].sinks
        return out
    if isinstance(node, Conditional):
        guard = _build(node.guard)
        gname = guard.rows[0][0]
        arms = [(node.then, True)]
        if node.orelse is not None:
            arms.append((node.orelse, False))
        out = _Fragment(list(guard.rows), list(guard.sources), [])
        for arm, sense in arms:
            frag = _build(arm)
            for row in frag.rows:
                if row[3] is not None:
                    raise ValueError(
                        f"nested conditional at task {row[0]!r}: one "
                        "(guard, sense) select slot per task")
                row[3] = (gname, sense)
            # gated tasks structurally depend on the guard, so nothing
            # can be mid-attempt when the deciding event cancels a branch
            for s in frag.sources:
                next(r for r in frag.rows if r[0] == s)[2].append(gname)
            out.rows += frag.rows
            out.sinks += frag.sinks
        return out
    if isinstance(node, Barrier):
        raise ValueError("barrier is only meaningful inside a chain")
    raise TypeError(f"not a workflow spec node: {node!r}")


def compile_spec(spec, *, name: str) -> "WorkflowGraph":
    """Lower a combinator spec to the :class:`WorkflowGraph` IR."""
    frag = _build(spec)
    names = [r[0] for r in frag.rows]
    if len(set(names)) != len(names):
        dupes = sorted({n for n in names if names.count(n) > 1})
        raise ValueError(f"duplicate task names in spec: {dupes}")
    idx = {n: i for i, n in enumerate(names)}
    # dedupe edges preserving first-seen order (lane + join links can
    # both land on a source when ranks collapse)
    deps = tuple(tuple(dict.fromkeys(r[2])) for r in frag.rows)
    guard = tuple(-1 if r[3] is None else idx[r[3][0]] for r in frag.rows)
    sense = tuple(False if r[3] is None else bool(r[3][1])
                  for r in frag.rows)
    return WorkflowGraph(name=str(name), tasks=tuple(names),
                         means=tuple(float(r[1]) for r in frag.rows),
                         deps=deps, cond_guard=guard, cond_sense=sense)


# --------------------------------------------------------------------------
# the IR
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class WorkflowGraph:
    """One compiled workflow: the IR every engine consumes.

    Frozen and hashable — field tuples only — so the graph itself is the
    static key of the lru-cached trial builders and the sweep bucket
    cores.  ``cond_guard[t] == -1`` marks an unconditional task; else it
    is the guard's task index and ``cond_sense[t]`` the outcome that
    keeps task ``t`` alive (see module docstring).
    """
    name: str
    tasks: Tuple[str, ...]
    means: Tuple[float, ...]
    deps: Tuple[Tuple[str, ...], ...]
    cond_guard: Tuple[int, ...] = ()
    cond_sense: Tuple[bool, ...] = ()

    def __post_init__(self):
        k = len(self.tasks)
        if not self.cond_guard:
            object.__setattr__(self, "cond_guard", (-1,) * k)
        if not self.cond_sense:
            object.__setattr__(self, "cond_sense", (False,) * k)
        if not (len(self.means) == len(self.deps) == len(self.cond_guard)
                == len(self.cond_sense) == k):
            raise ValueError(
                f"{self.name!r}: tasks/means/deps/cond lengths disagree")
        known = set(self.tasks)
        if len(known) != k:
            raise ValueError(f"{self.name!r}: duplicate task names")
        for t, ds in zip(self.tasks, self.deps):
            missing = set(ds) - known
            if missing:
                raise ValueError(f"{t}: unknown dependencies {missing}")
        from repro.core.dag import kahn_order   # dag imports manifest
        kahn_order(dict(zip(self.tasks, self.deps)))  # names any cycle
        closure = self._ancestors()
        for t, g in enumerate(self.cond_guard):
            if g < 0:
                continue
            if not 0 <= g < k:
                raise ValueError(f"{self.tasks[t]}: guard index {g} out "
                                 "of range")
            if self.cond_guard[g] >= 0:
                raise ValueError(
                    f"{self.tasks[t]}: guard {self.tasks[g]!r} is itself "
                    "conditional (nested conditionals are rejected)")
            if g not in closure[t]:
                raise ValueError(
                    f"{self.tasks[t]}: must depend (transitively) on its "
                    f"guard {self.tasks[g]!r} so cancellation can never "
                    "hit a running attempt")

    # -- core shape ------------------------------------------------------
    @property
    def K(self) -> int:
        return len(self.tasks)

    @functools.cached_property
    def index(self) -> Dict[str, int]:
        return {t: i for i, t in enumerate(self.tasks)}

    def dep_map(self) -> Dict[str, Tuple[str, ...]]:
        return dict(zip(self.tasks, self.deps))

    def _ancestors(self):
        idx = {t: i for i, t in enumerate(self.tasks)}
        anc = [set() for _ in self.tasks]
        for t in self.topo_order():
            for d in self.deps[t]:
                di = idx[d]
                anc[t].add(di)
                anc[t] |= anc[di]
        return anc

    # -- dependency masks (the vector engines' statics) ------------------
    @functools.cached_property
    def _dep_mask_np(self) -> np.ndarray:
        m = np.zeros((self.K, self.K), dtype=bool)
        for t, ds in enumerate(self.deps):
            for d in ds:
                m[t, self.index[d]] = True
        return m

    def dep_mask(self) -> np.ndarray:
        """(K, K) bool, ``mask[t, d]`` = task t needs task d (read-only)."""
        return self._dep_mask_np

    @functools.cached_property
    def has_deps(self) -> bool:
        return any(len(d) for d in self.deps)

    def member_sequences(self, flight: int) -> np.ndarray:
        """(F, K) member task orders — the §3.3.3 cyclic-shift
        linearisation (``core.dag.execution_sequence``), as indices."""
        from repro.core.dag import execution_sequence
        man = self.to_manifest(max(int(flight), 1))
        return np.array([[self.index[t] for t in execution_sequence(man, m)]
                         for m in range(int(flight))])

    # -- level schedules -------------------------------------------------
    def topo_order(self) -> Tuple[int, ...]:
        from repro.core.dag import kahn_order
        order = kahn_order(dict(zip(self.tasks, self.deps)))
        return tuple(self.index[t] for t in order)

    @functools.cached_property
    def _depths(self) -> Tuple[int, ...]:
        depth = [0] * self.K
        for t in self.topo_order():
            if self.deps[t]:
                depth[t] = 1 + max(depth[self.index[d]]
                                   for d in self.deps[t])
        return tuple(depth)

    def stage_depth(self) -> int:
        return max(self._depths) if self.K else 0

    def levels(self) -> Tuple[Tuple[int, ...], ...]:
        """Tasks grouped by stage depth — the level schedule."""
        return tuple(
            tuple(t for t in range(self.K) if self._depths[t] == lv)
            for lv in range(self.stage_depth() + 1))

    # -- conditional select masks ----------------------------------------
    @functools.cached_property
    def has_conditionals(self) -> bool:
        return any(g >= 0 for g in self.cond_guard)

    @property
    def cond_static(self):
        """``(cond_guard, cond_sense)`` when any task is gated, else
        ``None`` — the trial builders statically elide the select logic
        on ``None`` (bitwise the pre-conditional scan)."""
        if not self.has_conditionals:
            return None
        return (self.cond_guard, self.cond_sense)

    def flatten(self) -> "WorkflowGraph":
        """Drop the conditional select masks (deps kept): the stock
        baseline's view, where both branches run unconditionally."""
        if not self.has_conditionals:
            return self
        return WorkflowGraph(name=self.name, tasks=self.tasks,
                             means=self.means, deps=self.deps)

    # -- interop ---------------------------------------------------------
    def to_manifest(self, concurrency: int = 1):
        from repro.core.manifest import ActionManifest, FunctionSpec
        return ActionManifest(
            tuple(FunctionSpec(t, None, tuple(d))
                  for t, d in zip(self.tasks, self.deps)),
            concurrency=max(int(concurrency), 1), name=self.name)

    @functools.cached_property
    def manifest_hash(self) -> str:
        """sha256 of the canonical compiled content — the identity the
        sweep bucket keys and bench records carry for a compiled graph."""
        canon = repr((self.name, self.tasks, self.means, self.deps,
                      self.cond_guard, self.cond_sense))
        return hashlib.sha256(canon.encode()).hexdigest()[:16]
