"""JAX-native Raptor combinators: the state-sharing stream and preemption
semantics expressed as collective dataflow over a *flight axis* of the mesh.

On a real fleet each flight member is a separate executor group (pod or DP
slice) with its own latency/failure behaviour; these combinators express the
adopt-first-output rule so the same program runs under pjit on any mesh:

- ``first_finisher``      : every member contributes (value, latency); all
                            members adopt the min-latency member's value.
                            == the state-sharing broadcast + preemption.
- ``k_of_n_mean``         : mean over the k earliest/healthy members
                            (straggler-dropping gradient aggregation).
- ``masked_mean``         : mean over members with health=1; degrades
                            gracefully exactly like a reduced flight
                            (paper §3.3.2) and fails only if all fail (p^N).

All functions are written for use inside ``shard_map`` with a named axis.
"""
from __future__ import annotations

from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.moe import shard_map  # version-portable wrapper

P = jax.sharding.PartitionSpec


def _axis_rank(axis_name: str):
    return jax.lax.axis_index(axis_name)


def first_finisher(value, latency, axis_name: str):
    """Adopt the value of the member with the smallest latency.

    value: any pytree (same structure on every member); latency: scalar.
    Returns (winner_value, winner_index).  Cost: one all-gather of the
    scalar latencies + one psum of the value bytes.
    """
    lats = jax.lax.all_gather(latency, axis_name)           # [F]
    winner = jnp.argmin(lats)
    me = _axis_rank(axis_name)
    is_winner = (me == winner).astype(jnp.float32)

    def pick(v):
        contrib = v.astype(jnp.float32) * is_winner
        return jax.lax.psum(contrib, axis_name).astype(v.dtype)

    return jax.tree.map(pick, value), winner


def masked_mean(value, healthy, axis_name: str):
    """Mean over healthy members; returns (mean, n_healthy).

    healthy: scalar {0,1}.  If all members are unhealthy the result is 0 and
    n_healthy==0 — callers treat that as job failure (prob p^N).
    """
    h = healthy.astype(jnp.float32)
    n = jax.lax.psum(h, axis_name)
    denom = jnp.maximum(n, 1.0)

    def agg(v):
        return (jax.lax.psum(v.astype(jnp.float32) * h, axis_name)
                / denom).astype(v.dtype)

    return jax.tree.map(agg, value), n


def k_of_n_mean(value, latency, k: int, axis_name: str):
    """Mean over the k members with the smallest latency (drop stragglers).

    Deterministic tie-break by member index.
    """
    lats = jax.lax.all_gather(latency, axis_name)           # [F]
    f = lats.shape[0]
    order = jnp.argsort(lats)
    me = _axis_rank(axis_name)
    my_rank = jnp.nonzero(order == me, size=1)[0][0]
    keep = (my_rank < k).astype(jnp.float32)

    def agg(v):
        return (jax.lax.psum(v.astype(jnp.float32) * keep, axis_name)
                / float(k)).astype(v.dtype)

    return jax.tree.map(agg, value)


# --------------------------------------------------------------------------
# mesh-level wrappers
# --------------------------------------------------------------------------

def speculative_apply(fn, mesh, flight_axis: str, value_spec, *,
                      latency_fn=None):
    """Run ``fn(member_index, *args) -> (value, latency)`` on every member of
    the flight axis and adopt the first finisher's value everywhere.

    ``value_spec``: out PartitionSpec *inside* a member (without the flight
    axis).  Returns a function over global arrays.
    """
    def member_fn(*args):
        idx = jax.lax.axis_index(flight_axis)
        value, latency = fn(idx, *args)
        adopted, winner = first_finisher(value, latency, flight_axis)
        return adopted, winner

    def wrapped(*args):
        return shard_map(
            member_fn, mesh,
            in_specs=tuple(P() for _ in args),
            out_specs=(value_spec, P()),
        )(*args)

    return wrapped
