"""DAG construction and decorrelated execution sequences (paper §3.3.3).

Each executor linearises the manifest DAG by repeatedly searching — in
*reverse in-order*, starting from the sinks — for the first function whose
dependencies are all satisfied.  To decorrelate parallel executors, the
search order of candidate nodes is **cyclically shifted by the follower
index**, reproducing Table 3 exactly.
"""
from __future__ import annotations

import heapq
from typing import Dict, List, Mapping, Sequence, Tuple

from repro.core.manifest import ActionManifest


def kahn_order(dep_map: Mapping[str, Sequence[str]]) -> List[str]:
    """Kahn's algorithm over a name -> dependencies map: the ONE toposort
    shared by the scalar and vector paths (manifest validation, the IR's
    level schedules, the stock stage-depth walk).

    Nodes pop in declaration order among the currently-available set (a
    heap on declaration index), so the order is deterministic and matches
    the old per-engine polling loops it replaces.  Raises ``ValueError``
    **naming one cycle** when the map is not a DAG.
    """
    names = list(dep_map)
    pos = {n: i for i, n in enumerate(names)}
    remaining = {n: {d for d in dep_map[n] if d != n} for n in names}
    self_cycle = next((n for n in names if n in dep_map[n]), None)
    if self_cycle is not None:
        raise ValueError(
            f"dependency cycle: {self_cycle} -> {self_cycle}")
    dependents: Dict[str, List[str]] = {n: [] for n in names}
    for n, ds in remaining.items():
        for d in ds:
            dependents[d].append(n)
    ready = [pos[n] for n, ds in remaining.items() if not ds]
    heapq.heapify(ready)
    out: List[str] = []
    while ready:
        n = names[heapq.heappop(ready)]
        out.append(n)
        for m in dependents[n]:
            remaining[m].discard(n)
            if not remaining[m]:
                heapq.heappush(ready, pos[m])
    if len(out) != len(names):
        # walk the leftover subgraph until a node repeats: that loop IS
        # a cycle, and the error names it (start at the first declared
        # leftover so the message is hash-seed independent)
        left = {n for n in names if remaining[n]}
        path, seen, n = [], {}, next(n for n in names if remaining[n])
        while n not in seen:
            seen[n] = len(path)
            path.append(n)
            n = next(d for d in dep_map[n] if d in left)
        cyc = path[seen[n]:] + [n]
        raise ValueError(f"dependency cycle: {' -> '.join(cyc)}")
    return out


def validate_acyclic(manifest: ActionManifest) -> List[str]:
    """Toposort the manifest via :func:`kahn_order`; raises ValueError
    naming a cycle.  Returns one topo order."""
    return kahn_order(manifest.dependency_map())


def _search_order(manifest: ActionManifest) -> List[str]:
    """Reverse in-order node visitation: sinks first, then their
    dependencies depth-first in REVERSED declaration order (the paper walks
    the DAG 'starting at the end ... in the reverse direction'; this
    ordering reproduces Table 3 exactly — see test_core_dag)."""
    children = manifest.dependency_map()
    is_dep = {d for f in manifest.functions for d in f.dependencies}
    sinks = [n for n in manifest.names if n not in is_dep]
    order: List[str] = []
    seen = set()

    def visit(n: str):
        if n in seen:
            return
        seen.add(n)
        order.append(n)
        for d in children[n]:
            visit(d)

    for s in sinks:
        visit(s)
    return order


def execution_sequence(manifest: ActionManifest, follower_index: int) -> List[str]:
    """The order in which executor ``follower_index`` runs the functions.

    At every step, collect the runnable candidates in reverse in-order
    search order and apply a cyclic shift **by the follower index** to the
    candidate list — executor i takes the i-th runnable (mod count).  This
    is the paper's §3.3.3 shift applied at the scan level; it reproduces
    Table 3 exactly AND spreads any flight maximally over every DAG shape
    (a static whole-list rotation collides executors on fan-out nodes —
    see test_core_dag.py for both properties).
    """
    validate_acyclic(manifest)
    base = _search_order(manifest)
    n = len(base)
    done: List[str] = []
    deps = manifest.dependency_map()
    while len(done) < n:
        cands = [c for c in base
                 if c not in done and all(d in done for d in deps[c])]
        if not cands:  # pragma: no cover - unreachable on a validated DAG
            raise RuntimeError("no runnable function found")
        done.append(cands[follower_index % len(cands)])
    return done


def sequences_for_flight(manifest: ActionManifest) -> List[List[str]]:
    return [execution_sequence(manifest, i) for i in range(manifest.concurrency)]


def ready_functions(manifest: ActionManifest, completed: Sequence[str]) -> Tuple[str, ...]:
    deps = manifest.dependency_map()
    done = set(completed)
    return tuple(n for n in manifest.names
                 if n not in done and all(d in done for d in deps[n]))
