"""DAG construction and decorrelated execution sequences (paper §3.3.3).

Each executor linearises the manifest DAG by repeatedly searching — in
*reverse in-order*, starting from the sinks — for the first function whose
dependencies are all satisfied.  To decorrelate parallel executors, the
search order of candidate nodes is **cyclically shifted by the follower
index**, reproducing Table 3 exactly.
"""
from __future__ import annotations

from typing import Dict, List, Sequence, Tuple

from repro.core.manifest import ActionManifest


def validate_acyclic(manifest: ActionManifest) -> List[str]:
    """Kahn toposort; raises ValueError on cycles.  Returns one topo order."""
    deps = {f.name: set(f.dependencies) for f in manifest.functions}
    out: List[str] = []
    ready = [n for n, d in deps.items() if not d]
    deps = {n: set(d) for n, d in deps.items()}
    dependents: Dict[str, List[str]] = {n: [] for n in deps}
    for n, d in list(deps.items()):
        for p in d:
            dependents[p].append(n)
    while ready:
        n = ready.pop(0)
        out.append(n)
        for m in dependents[n]:
            deps[m].discard(n)
            if not deps[m]:
                ready.append(m)
    if len(out) != len(manifest.functions):
        raise ValueError("manifest DAG has a cycle")
    return out


def _search_order(manifest: ActionManifest) -> List[str]:
    """Reverse in-order node visitation: sinks first, then their
    dependencies depth-first in REVERSED declaration order (the paper walks
    the DAG 'starting at the end ... in the reverse direction'; this
    ordering reproduces Table 3 exactly — see test_core_dag)."""
    children = manifest.dependency_map()
    is_dep = {d for f in manifest.functions for d in f.dependencies}
    sinks = [n for n in manifest.names if n not in is_dep]
    order: List[str] = []
    seen = set()

    def visit(n: str):
        if n in seen:
            return
        seen.add(n)
        order.append(n)
        for d in children[n]:
            visit(d)

    for s in sinks:
        visit(s)
    return order


def execution_sequence(manifest: ActionManifest, follower_index: int) -> List[str]:
    """The order in which executor ``follower_index`` runs the functions.

    At every step, collect the runnable candidates in reverse in-order
    search order and apply a cyclic shift **by the follower index** to the
    candidate list — executor i takes the i-th runnable (mod count).  This
    is the paper's §3.3.3 shift applied at the scan level; it reproduces
    Table 3 exactly AND spreads any flight maximally over every DAG shape
    (a static whole-list rotation collides executors on fan-out nodes —
    see test_core_dag.py for both properties).
    """
    validate_acyclic(manifest)
    base = _search_order(manifest)
    n = len(base)
    done: List[str] = []
    deps = manifest.dependency_map()
    while len(done) < n:
        cands = [c for c in base
                 if c not in done and all(d in done for d in deps[c])]
        if not cands:  # pragma: no cover - unreachable on a validated DAG
            raise RuntimeError("no runnable function found")
        done.append(cands[follower_index % len(cands)])
    return done


def sequences_for_flight(manifest: ActionManifest) -> List[List[str]]:
    return [execution_sequence(manifest, i) for i in range(manifest.concurrency)]


def ready_functions(manifest: ActionManifest, completed: Sequence[str]) -> Tuple[str, ...]:
    deps = manifest.dependency_map()
    done = set(completed)
    return tuple(n for n in manifest.names
                 if n not in done and all(d in done for d in deps[n]))
