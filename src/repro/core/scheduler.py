"""The Raptor execution engine (paper §3.2–§3.3): flights of peer executors
speculatively running a manifest with state sharing and preemption.

This is the *real* (non-simulated) engine: executors are threads (one per
flight member — the stand-in for one process per serverless sandbox), the
state-sharing stream is an in-process broadcast board (the stand-in for the
SCTP mesh; on a multi-host deployment each executor is a separate process
and the board is backed by the collective fabric), and preemption is a
cooperative cancellation token checked by the function between work slices
(the stand-in for POSIX job-control signals, with the same at-boundary
delivery granularity).

Functions receive a ``TaskContext`` and must return their output; they may
call ``ctx.sleep(dt)`` for interruptible waits and must treat
``ctx.cancelled`` as a preemption request.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any, Callable, Dict, List, Optional

from repro.core.dag import execution_sequence, validate_acyclic
from repro.core.manifest import ActionManifest, ExecutionContext


class Preempted(Exception):
    """Raised inside a function when its result arrived from a peer."""


@dataclasses.dataclass
class TaskResult:
    name: str
    value: Any
    error: Optional[BaseException]
    executor: int
    t_finish: float
    attempt: int = 0


class StateStream:
    """State-sharing stream: first non-error result per function wins
    (paper §3.3.4); later duplicates are discarded.  ``latency`` models the
    half-RTT broadcast delivery delay of the SCTP stream."""

    def __init__(self, latency: float = 0.0):
        self._lock = threading.Lock()
        self._results: Dict[str, TaskResult] = {}
        self._errors: Dict[str, set] = {}
        self._event = threading.Condition(self._lock)
        self.latency = latency
        self.duplicates = 0

    def publish(self, res: TaskResult) -> bool:
        """Returns True if this was the winning (first) result."""
        with self._lock:
            cur = self._results.get(res.name)
            if res.error is not None:
                # errors never overwrite a success, but every one is counted
                # per distinct (executor, attempt) so waiters can detect a
                # dead task: with an R-retry policy a task is only dead
                # after size * (1 + R) failed attempts, not size failures
                self._errors.setdefault(res.name, set()).add(
                    (res.executor, res.attempt))
                if cur is None:
                    self._results[res.name] = res
                self._event.notify_all()
                return cur is None
            if cur is not None and cur.error is None:
                self.duplicates += 1
                return False
            self._results[res.name] = res
            self._event.notify_all()
            return True

    def error_count(self, name: str) -> int:
        """Distinct (executor, attempt) failures recorded for ``name``."""
        with self._lock:
            return len(self._errors.get(name, ()))

    def visible(self, name: str, now: Optional[float] = None) -> Optional[TaskResult]:
        """Result of ``name`` if its broadcast has been delivered."""
        with self._lock:
            r = self._results.get(name)
        if r is None or r.error is not None:
            return None
        now = time.monotonic() if now is None else now
        if r.t_finish + self.latency <= now:
            return r
        return None

    def completed(self) -> Dict[str, TaskResult]:
        with self._lock:
            return {k: v for k, v in self._results.items() if v.error is None}

    def wait_all(self, names, timeout: float,
                 dead_after: Optional[int] = None) -> bool:
        """Block until every name has an error-free result, the timeout
        elapses, or — when ``dead_after`` is given — some task has
        accumulated ``dead_after`` distinct failed attempts with no
        success.  ``dead_after`` is the flight's whole attempt budget:
        ``size * (1 + max_retries)`` under a recovery policy (each member
        retries a failed task up to ``max_retries`` times before moving
        on), collapsing to ``size`` without one — once the budget is
        burned the task can never complete and the flight fails fast
        instead of waiting out the full timeout."""
        deadline = time.monotonic() + timeout
        with self._lock:
            while True:
                ok = all(n in self._results and self._results[n].error is None
                         for n in names)
                if ok:
                    return True
                if dead_after is not None:
                    dead = any(
                        len(self._errors.get(n, ())) >= dead_after
                        and (n not in self._results
                             or self._results[n].error is not None)
                        for n in names)
                    if dead:
                        return False
                rem = deadline - time.monotonic()
                if rem <= 0:
                    return False
                self._event.wait(rem)


@dataclasses.dataclass
class TaskContext:
    """Handed to every function invocation."""
    manifest_name: str
    task_name: str
    follower_index: int
    context: ExecutionContext
    inputs: Dict[str, Any]
    _cancel: threading.Event = dataclasses.field(default_factory=threading.Event)

    @property
    def cancelled(self) -> bool:
        return self._cancel.is_set()

    def sleep(self, dt: float, slice_s: float = 0.002):
        """Interruptible sleep — the preemption point (signal delivery)."""
        end = time.monotonic() + dt
        while True:
            if self._cancel.is_set():
                raise Preempted(self.task_name)
            rem = end - time.monotonic()
            if rem <= 0:
                return
            time.sleep(min(slice_s, rem))

    def checkpoint(self):
        if self._cancel.is_set():
            raise Preempted(self.task_name)


@dataclasses.dataclass
class ExecutorReport:
    index: int
    executed: List[str]
    skipped: List[str]
    preempted: List[str]
    failed: List[str]
    busy_time: float


@dataclasses.dataclass
class FlightReport:
    outputs: Dict[str, Any]
    ok: bool
    elapsed: float
    executors: List[ExecutorReport]
    duplicates: int

    @property
    def total_busy(self) -> float:
        return sum(e.busy_time for e in self.executors)


class _Executor(threading.Thread):
    def __init__(self, flight: "Flight", index: int):
        super().__init__(daemon=True, name=f"raptor-exec-{index}")
        self.flight = flight
        self.index = index
        self.report = ExecutorReport(index, [], [], [], [], 0.0)
        self.current_ctx: Optional[TaskContext] = None
        self._die = threading.Event()

    def preempt_current(self, task_name: str):
        ctx = self.current_ctx
        if ctx is not None and ctx.task_name == task_name:
            ctx._cancel.set()

    def kill(self):
        self._die.set()
        ctx = self.current_ctx
        if ctx is not None:
            ctx._cancel.set()

    def run(self):
        fl = self.flight
        seq = execution_sequence(fl.manifest, self.index)
        for name in seq:
            if self._die.is_set():
                break
            if fl.stream.visible(name) is not None:
                self.report.skipped.append(name)
                continue
            spec = fl.manifest.spec(name)
            inputs = {d: fl.stream.completed()[d].value
                      for d in spec.dependencies
                      if d in fl.stream.completed()}
            # retry loop: under a recovery policy a member re-attempts its
            # own failed invocation (backoff between attempts) before
            # moving on; every failed attempt is published so the stream's
            # dead-task budget counts attempts, not members
            for attempt in range(fl.attempt_budget):
                if self._die.is_set():
                    break
                if attempt and fl.stream.visible(name) is not None:
                    break          # a peer won while we were backing off
                ctx = TaskContext(fl.manifest.name, name, self.index,
                                  fl.context.fork(self.index) if self.index else fl.context,
                                  inputs)
                self.current_ctx = ctx
                fl.register_running(self.index, name)
                t0 = time.monotonic()
                try:
                    value = spec.fn(ctx) if spec.fn is not None else None
                    res = TaskResult(name, value, None, self.index,
                                     time.monotonic(), attempt)
                    self.report.executed.append(name)
                    won = fl.stream.publish(res)
                    if won:
                        fl.on_first_completion(name, self.index)
                    break
                except Preempted:
                    self.report.preempted.append(name)
                    break
                except Exception as e:  # noqa: BLE001 - executor failure path
                    self.report.failed.append(name)
                    fl.stream.publish(TaskResult(name, None, e, self.index,
                                                 time.monotonic(), attempt))
                finally:
                    self.report.busy_time += time.monotonic() - t0
                    fl.register_running(self.index, None)
                    self.current_ctx = None
                if attempt + 1 < fl.attempt_budget:
                    # backoff is idle time, not busy time
                    self._die.wait(fl.backoff_s(attempt))


class Flight:
    """N peer executors speculatively running one manifest invocation."""

    def __init__(self, manifest: ActionManifest, context: Optional[ExecutionContext] = None,
                 size: Optional[int] = None, stream_latency: float = 0.0,
                 recovery: Optional[Any] = None):
        validate_acyclic(manifest)
        self.manifest = manifest
        self.context = context or ExecutionContext.fresh()
        # elastic degradation (paper §3.3.2): fewer members than requested is
        # a smaller flight, not a failure.
        self.size = max(1, size if size is not None else manifest.concurrency)
        # ``recovery`` is duck-typed (anything exposing max_retries /
        # backoff_ms / backoff_jitter — e.g. repro.sim.policies.
        # RecoveryPolicy) so the live engine carries no sim dependency;
        # None keeps the historical one-attempt-per-member behavior
        self.recovery = recovery
        self.attempt_budget = 1 + int(getattr(recovery, "max_retries", 0) or 0)
        self.stream = StateStream(latency=stream_latency)
        self._running: Dict[int, Optional[str]] = {}
        self._lock = threading.Lock()
        self._executors: List[_Executor] = []

    def register_running(self, idx: int, name: Optional[str]):
        with self._lock:
            self._running[idx] = name

    def backoff_s(self, attempt: int) -> float:
        """Seconds to wait before retry ``attempt + 1`` (exponential;
        jitter is deterministic-free here — the live engine's clock noise
        already decorrelates members)."""
        base = float(getattr(self.recovery, "backoff_ms", 0.0) or 0.0)
        return base * (2.0 ** attempt) / 1000.0

    def on_first_completion(self, name: str, winner: int):
        """Broadcast receipt: preempt peers still running ``name``
        (paper §3.3.4)."""
        for ex in self._executors:
            if ex.index != winner:
                ex.preempt_current(name)

    def run(self, timeout: float = 60.0) -> FlightReport:
        t0 = time.monotonic()
        self._executors = [_Executor(self, i) for i in range(self.size)]
        for ex in self._executors:
            ex.start()
        ok = self.stream.wait_all(self.manifest.names, timeout,
                                  dead_after=self.size * self.attempt_budget)
        # flight complete: reclaim everything still running
        for ex in self._executors:
            ex.kill()
        for ex in self._executors:
            ex.join(timeout=5.0)
        outputs = {k: v.value for k, v in self.stream.completed().items()}
        return FlightReport(
            outputs=outputs,
            ok=ok,
            elapsed=time.monotonic() - t0,
            executors=[ex.report for ex in self._executors],
            duplicates=self.stream.duplicates,
        )


class RaptorScheduler:
    """Top-level entry: schedules manifest invocations onto a bounded pool
    of executor slots, forming (possibly reduced) flights."""

    def __init__(self, num_workers: int = 8, stream_latency: float = 0.0):
        self.num_workers = num_workers
        self.stream_latency = stream_latency
        self._slots = threading.Semaphore(num_workers)

    def invoke(self, manifest: ActionManifest,
               params: Optional[Dict[str, Any]] = None,
               timeout: float = 60.0,
               recovery: Optional[Any] = None) -> FlightReport:
        want = manifest.concurrency
        got = 0
        for _ in range(want):
            if self._slots.acquire(blocking=(got == 0)):
                got += 1
        try:
            ctx = ExecutionContext.fresh(user_params=params or {})
            flight = Flight(manifest, ctx, size=got,
                            stream_latency=self.stream_latency,
                            recovery=recovery)
            return flight.run(timeout=timeout)
        finally:
            for _ in range(got):
                self._slots.release()
