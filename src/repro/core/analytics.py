"""Order-statistics theory used by the paper (§4.2.1 equation, Figure 8).

For i.i.d. exponential task times Z_i with mean 1:
  E[min of n]  = 1/n
  E[max of n]  = H_n (harmonic number)
  paper's prediction for the 2-task / flight-2 SSH workload:
      E[T_Raptor] / E[T_OpenWhisk] = 2 E[min(Z1,Z2)] / E[max(Z1,Z2)] = 2/3.

Failure model (Figure 8): task failure probability p, N parallel tasks:
  fork-join job failure      = 1 - (1-p)^N      (all must succeed)
  Raptor flight job failure  = p^N              (any one suffices)
"""
from __future__ import annotations

import math
from typing import Sequence

import numpy as np


def harmonic(n: int) -> float:
    return sum(1.0 / i for i in range(1, n + 1))


def e_min_exp(n: int, mean: float = 1.0) -> float:
    return mean / n


def e_max_exp(n: int, mean: float = 1.0) -> float:
    return mean * harmonic(n)


def raptor_speedup_prediction(num_tasks: int, flight: int) -> float:
    """E[T_Raptor]/E[T_baseline] for `num_tasks` independent exp(1) tasks.

    Raptor races the whole flight task-by-task (each task completes at the
    min over `flight` executors, tasks in series); the baseline fork-join
    waits for the max over the parallel tasks.
    """
    t_raptor = num_tasks * e_min_exp(flight)
    t_base = e_max_exp(num_tasks)
    return t_raptor / t_base


def raptor_plateau_prediction(num_tasks: int, flight: int) -> float:
    """Corrected F>>K plateau: K * E[min_{F/K}] / E[max_K].

    The paper's K*E[min_F]/E[max_K] form silently assumes all F members
    race every task in lockstep.  Under the §3.3.3 shifted sequences (or
    ANY admissible per-member order) the flight splits over the K tasks,
    so only ~F/K members race a given task concurrently — the effective
    race width is F/K, not F (EXPERIMENTS.md has the derivation; measured
    0.198 vs corrected 0.167 vs paper 0.083 at F=16, K=2).  For F <= K
    the split does not bind (finishers re-race the remaining tasks almost
    immediately) and the paper's form stays the better model — this
    function is the wide-flight asymptote, not a general replacement.
    """
    width = max(flight // num_tasks, 1)
    return num_tasks * e_min_exp(width) / e_max_exp(num_tasks)


def forkjoin_failure(p: float, n: int) -> float:
    return 1.0 - (1.0 - p) ** n


def raptor_failure(p: float, n: int) -> float:
    """The paper's Figure 8 expression: p^N (per-task replication bound)."""
    return p ** n


def raptor_failure_exact(p: float, n_tasks: int, flight: int = None) -> float:
    """Exact job failure for an N-task manifest on a flight of size F with
    error-broadcast semantics (§3.3.4): a task is lost only if all F
    attempts error; the job fails if any task is lost.  The paper's p^N is
    the single-task term; the sim matches this exact form (see
    tests/test_sim_repro.py)."""
    f = flight if flight is not None else n_tasks
    return 1.0 - (1.0 - p ** f) ** n_tasks


def response_ratio_paper() -> float:
    """The paper's headline number: 2*E[min]/E[max] = 1/1.5 ~ 0.67."""
    return raptor_speedup_prediction(num_tasks=2, flight=2)


# --------------------------------------------------------------------------
# on-device (JAX) batched reductions — used by sim/vector.py
# --------------------------------------------------------------------------
# jax is imported lazily so the scalar simulator keeps working on a bare
# numpy-only interpreter; every function here accepts/returns jnp arrays and
# is safe to call under jit/vmap.

def summarize_batch(samples):
    """On-device analogue of :func:`summarize` over a 1-D sample batch.

    Returns a dict of 0-d jnp arrays (floats once pulled off device), so a
    jitted sweep can compute every table statistic without a host round-trip.
    """
    import jax.numpy as jnp
    a = jnp.asarray(samples)
    if not jnp.issubdtype(a.dtype, jnp.floating):
        a = a.astype(jnp.float32)
    mean = jnp.mean(a)
    # one fused percentile call: a single device sort instead of three
    qs = jnp.percentile(a, jnp.array([50.0, 90.0, 99.0]))
    return {
        "mean": mean,
        "median": qs[0],
        "p90": qs[1],
        "p99": qs[2],
        "scv": jnp.var(a) / (mean * mean + 1e-12),
        "n": a.size,
    }


def summarize_masked_batch(samples, ok):
    """Success-conditioned :func:`summarize_batch`, safe under jit/vmap.

    Failed jobs' "responses" are failure-detection times, not delays, so
    delay statistics condition on ``ok``; the failure accounting rides
    alongside (``fail_rate`` over everything, ``n_failed`` explicit).
    Masked percentiles sort with failures pushed to +inf and interpolate
    over the first ``n_ok`` order statistics (numpy's linear rule), so a
    device-sharded sweep can reduce every config's summary on-device and
    ship scalars home instead of raw sample batches.  With ``n_ok == 0``
    the delay stats come back NaN and ``n`` is 0, mirroring the host-side
    summaries.
    """
    import jax.numpy as jnp
    a = jnp.asarray(samples).ravel()
    if not jnp.issubdtype(a.dtype, jnp.floating):
        a = a.astype(jnp.float32)
    m = jnp.asarray(ok, dtype=bool).ravel()
    n_ok = jnp.sum(m)
    denom = jnp.maximum(n_ok, 1)
    s = jnp.sort(jnp.where(m, a, jnp.inf))
    nan = jnp.float32(jnp.nan)

    def q(p):
        idx = p / 100.0 * (denom - 1)
        lo = jnp.clip(jnp.floor(idx).astype(jnp.int32), 0, a.size - 1)
        hi = jnp.clip(jnp.ceil(idx).astype(jnp.int32), 0, a.size - 1)
        w = (idx - lo).astype(s.dtype)
        return jnp.where(n_ok > 0, s[lo] * (1 - w) + s[hi] * w, nan)

    mean = jnp.where(n_ok > 0, jnp.sum(jnp.where(m, a, 0.0)) / denom, nan)
    var = jnp.sum(jnp.where(m, (a - mean) ** 2, 0.0)) / denom
    return {
        "mean": mean,
        "median": q(50.0),
        "p90": q(90.0),
        "p99": q(99.0),
        "scv": var / (mean * mean + 1e-12),
        "n": n_ok,
        "fail_rate": 1.0 - n_ok / a.size,
        "n_failed": a.size - n_ok,
    }


def emp_min_mean(z, axis: int = -1):
    """E[min] estimate: mean over the batch of the min over ``axis``."""
    import jax.numpy as jnp
    return jnp.mean(jnp.min(jnp.asarray(z), axis=axis))


def emp_max_mean(z, axis: int = -1):
    """E[max] estimate: mean over the batch of the max over ``axis``."""
    import jax.numpy as jnp
    return jnp.mean(jnp.max(jnp.asarray(z), axis=axis))


def flight_fail_rate_batch(fail):
    """Job failure rate from a (trials, flight, tasks) attempt-error tensor.

    A task is lost only when every flight member's attempt errors (§3.3.4
    error-broadcast semantics); the job fails if any task is lost — the
    empirical counterpart of :func:`raptor_failure_exact`.
    """
    import jax.numpy as jnp
    f = jnp.asarray(fail, dtype=bool)
    task_lost = jnp.all(f, axis=1)          # (trials, tasks)
    return jnp.mean(jnp.any(task_lost, axis=-1))


def forkjoin_fail_rate_batch(fail):
    """Stock fork-join failure rate from a (trials, tasks) error tensor:
    the job fails when any of its single-attempt tasks errors."""
    import jax.numpy as jnp
    return jnp.mean(jnp.any(jnp.asarray(fail, dtype=bool), axis=-1))


def response_ratio_batch(t_raptor, t_stock):
    """Mean-response ratio E[T_Raptor]/E[T_stock] from two sample batches."""
    import jax.numpy as jnp
    return jnp.mean(jnp.asarray(t_raptor)) / jnp.mean(jnp.asarray(t_stock))


# --------------------------------------------------------------------------
# empirical helpers
# --------------------------------------------------------------------------

def summarize(samples: Sequence[float]) -> dict:
    a = np.asarray(samples, dtype=np.float64)
    return {
        "mean": float(a.mean()),
        "median": float(np.median(a)),
        "p90": float(np.percentile(a, 90)),
        "p99": float(np.percentile(a, 99)),
        "scv": float(a.var() / (a.mean() ** 2 + 1e-12)),
        "n": int(a.size),
    }


def mc_flight_time(num_tasks: int, flight: int, n_samples: int = 200_000,
                   rotated: bool = True, seed: int = 0) -> dict:
    """Monte-Carlo of the flight completion time under exp(1) tasks.

    rotated=True models the paper's cyclic-shift sequences with state
    sharing: the flight finishes when the union of per-executor progress
    covers every task (each executor skips tasks already broadcast).
    rotated=False models pure task-by-task racing: sum of min-order stats.
    """
    rng = np.random.default_rng(seed)
    if not rotated:
        t = rng.exponential(size=(n_samples, num_tasks, flight)).min(axis=2).sum(axis=1)
        return summarize(t)
    # event-driven per sample with true preemption: when a task first
    # completes anywhere, members currently running it are preempted at
    # that instant and immediately start their next pending task.
    times = np.empty(n_samples)
    seqs = [list(np.roll(np.arange(num_tasks), -e)) for e in range(flight)]
    z = rng.exponential(size=(n_samples, flight, 2 * num_tasks + 2))
    for s in range(n_samples):
        completed: dict = {}
        draw_i = [0] * flight
        cur = [None] * flight          # (task, finish_time) or None (idle)
        ptr = [0] * flight

        def start_next(e, now):
            while ptr[e] < num_tasks and seqs[e][ptr[e]] in completed:
                ptr[e] += 1
            if ptr[e] >= num_tasks:
                cur[e] = None
                return
            t_ = seqs[e][ptr[e]]
            cur[e] = (t_, now + z[s, e, draw_i[e]])
            draw_i[e] = min(draw_i[e] + 1, z.shape[2] - 1)
            ptr[e] += 1

        for e in range(flight):
            start_next(e, 0.0)
        while len(completed) < num_tasks:
            running = [(c[1], e) for e, c in enumerate(cur) if c is not None]
            if not running:
                break
            fin, e = min(running)
            task = cur[e][0]
            if task not in completed:
                completed[task] = fin
                # preempt peers running this task
                for pe, c in enumerate(cur):
                    if pe != e and c is not None and c[0] == task:
                        start_next(pe, fin)
            start_next(e, fin)
        times[s] = max(completed.values()) if completed else 0.0
    return summarize(times)


# --------------------------------------------------------------------------
# independence-prediction under a brownout mixture (sim/faults.py)
# --------------------------------------------------------------------------
# The paper's §4.2.1 predictions treat the flight members' service times as
# mutually independent.  Under AZ brownouts the stationary marginal is a
# MIXTURE — with probability pi the member's AZ is degraded and its draws
# inflate — and the independence assumption becomes a claim about the
# degradation indicators: with per-AZ (i.i.d.) brownouts the mixture draws
# stay independent across members and the order-statistics prediction
# still holds; with one shared (correlated) process every member degrades
# together and the prediction breaks (experiments.fault_sweep measures
# exactly this gap against the open-loop engine).

def _mixture_draws(rng, shape, dist: str, mean: float, cv: float,
                   offset: float):
    if dist == "exp":
        z = rng.exponential(mean, shape)
    elif dist == "lognorm":
        sigma2 = math.log(1.0 + cv * cv)
        mu = math.log(mean) - sigma2 / 2.0
        z = rng.lognormal(mu, math.sqrt(sigma2), shape)
    else:
        raise ValueError(f"unknown dist {dist!r}")
    return z + offset


def mc_flight_time_mixture(num_tasks: int, flight: int, *,
                           p_deg: float = 0.0, inflation: float = 1.0,
                           correlated: bool = False, dist: str = "exp",
                           mean: float = 1.0, cv: float = 1.0,
                           offset: float = 0.0, n_samples: int = 20_000,
                           seed: int = 0) -> dict:
    """Raptor flight completion time under the brownout service mixture.

    Each member's AZ is degraded with probability ``p_deg`` (the CTMC's
    stationary point, :attr:`FaultProfile.stationary_degraded`), inflating
    every draw it serves by ``inflation`` for the whole invocation (the
    open-loop stationary-snapshot semantics).  ``correlated=False`` draws
    the indicators i.i.d. per member — the independence prediction;
    ``correlated=True`` shares ONE indicator across the flight — the
    regime the prediction cannot see.  Same cyclic-shift event-driven
    race as :func:`mc_flight_time`.
    """
    rng = np.random.default_rng(seed)
    nd = 2 * num_tasks + 2
    z = _mixture_draws(rng, (n_samples, flight, nd), dist, mean, cv, offset)
    deg = rng.random((n_samples, 1 if correlated else flight)) < p_deg
    z = z * np.where(deg, inflation, 1.0)[:, :, None]
    times = np.empty(n_samples)
    seqs = [list(np.roll(np.arange(num_tasks), -e)) for e in range(flight)]
    for s in range(n_samples):
        completed: dict = {}
        draw_i = [0] * flight
        cur = [None] * flight
        ptr = [0] * flight

        def start_next(e, now):
            while ptr[e] < num_tasks and seqs[e][ptr[e]] in completed:
                ptr[e] += 1
            if ptr[e] >= num_tasks:
                cur[e] = None
                return
            t_ = seqs[e][ptr[e]]
            cur[e] = (t_, now + z[s, e, draw_i[e]])
            draw_i[e] = min(draw_i[e] + 1, nd - 1)
            ptr[e] += 1

        for e in range(flight):
            start_next(e, 0.0)
        while len(completed) < num_tasks:
            running = [(c[1], e) for e, c in enumerate(cur) if c is not None]
            if not running:
                break
            fin, e = min(running)
            task = cur[e][0]
            if task not in completed:
                completed[task] = fin
                for pe, c in enumerate(cur):
                    if pe != e and c is not None and c[0] == task:
                        start_next(pe, fin)
            start_next(e, fin)
        times[s] = max(completed.values()) if completed else 0.0
    return summarize(times)


def mc_forkjoin_mixture(num_tasks: int, *, p_deg: float = 0.0,
                        inflation: float = 1.0, correlated: bool = False,
                        dist: str = "exp", mean: float = 1.0,
                        cv: float = 1.0, offset: float = 0.0,
                        n_samples: int = 20_000, seed: int = 0) -> dict:
    """Stock fork-join completion (max over tasks) under the same service
    mixture — the denominator of the mixture speedup prediction.  Tasks
    spread round-robin over AZs, so per-task indicators are i.i.d. in the
    independent regime and shared in the correlated one."""
    rng = np.random.default_rng(seed)
    z = _mixture_draws(rng, (n_samples, num_tasks), dist, mean, cv, offset)
    deg = rng.random((n_samples, 1 if correlated else num_tasks)) < p_deg
    z = z * np.where(deg, inflation, 1.0)
    return summarize(z.max(axis=1))


def mixture_speedup_prediction(num_tasks: int, flight: int, *,
                               p_deg: float, inflation: float,
                               correlated: bool = False, dist: str = "exp",
                               mean: float = 1.0, cv: float = 1.0,
                               offset: float = 0.0,
                               n_samples: int = 20_000,
                               seed: int = 0) -> float:
    """E[T_Raptor]/E[T_stock] under the brownout mixture — the §4.2.1
    speedup prediction lifted to a degraded-but-independent cluster.  With
    ``correlated=False`` this is what an independence-assuming predictor
    forecasts; the fault_sweep experiment holds it against the measured
    ratio in both brownout regimes."""
    r = mc_flight_time_mixture(
        num_tasks, flight, p_deg=p_deg, inflation=inflation,
        correlated=correlated, dist=dist, mean=mean, cv=cv, offset=offset,
        n_samples=n_samples, seed=seed)
    s = mc_forkjoin_mixture(
        num_tasks, p_deg=p_deg, inflation=inflation, correlated=correlated,
        dist=dist, mean=mean, cv=cv, offset=offset, n_samples=n_samples,
        seed=seed + 1)
    return r["mean"] / s["mean"]
