"""Action manifests and execution contexts (paper §3.3.1–§3.3.2).

An *action manifest* indexes the user functions of a workflow by name,
declares their dependencies (a DAG), and sets the flight concurrency
(Table 1).  An *execution context* wraps user parameters with the metadata
Raptor adds during an action fork (Table 2): context UUID, leader address,
follower index.
"""
from __future__ import annotations

import dataclasses
import uuid as _uuid
from typing import Any, Callable, Dict, Mapping, Optional, Sequence, Tuple


@dataclasses.dataclass(frozen=True)
class FunctionSpec:
    """One row of an action manifest."""
    name: str
    fn: Optional[Callable] = None          # the executable ("Location")
    dependencies: Tuple[str, ...] = ()
    # resources consumed while running (for capacity accounting)
    cost: float = 1.0


@dataclasses.dataclass(frozen=True)
class ActionManifest:
    """DAG of functions + flight concurrency (paper Table 1)."""
    functions: Tuple[FunctionSpec, ...]
    concurrency: int = 1
    name: str = "manifest"

    def __post_init__(self):
        names = [f.name for f in self.functions]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate function names in manifest: {names}")
        known = set(names)
        for f in self.functions:
            missing = set(f.dependencies) - known
            if missing:
                raise ValueError(f"{f.name}: unknown dependencies {missing}")
        if self.concurrency < 1:
            raise ValueError("concurrency must be >= 1")
        # a cyclic manifest dies HERE, naming the cycle — not deep inside
        # an engine's toposort (function-level import: core.dag imports
        # this module at its top level)
        from repro.core.dag import kahn_order
        kahn_order({f.name: f.dependencies for f in self.functions})
        # name -> spec index for O(1) lookups; written through
        # object.__setattr__ (frozen dataclass) and excluded from the
        # generated __eq__/__hash__, which cover declared fields only
        object.__setattr__(self, "_by_name",
                           {f.name: f for f in self.functions})

    @property
    def names(self) -> Tuple[str, ...]:
        return tuple(f.name for f in self.functions)

    def spec(self, name: str) -> FunctionSpec:
        return self._by_name[name]

    def dependency_map(self) -> Dict[str, Tuple[str, ...]]:
        return {f.name: f.dependencies for f in self.functions}


@dataclasses.dataclass(frozen=True)
class ExecutionContext:
    """Invocation metadata added by the action fork (paper Table 2)."""
    context_uuid: str
    leader_address: str
    follower_index: int                    # 0 = flight leader
    user_params: Mapping[str, Any] = dataclasses.field(default_factory=dict)

    @classmethod
    def fresh(cls, leader_address: str = "local", follower_index: int = 0,
              user_params: Optional[Mapping[str, Any]] = None):
        return cls(context_uuid=str(_uuid.uuid4()),
                   leader_address=leader_address,
                   follower_index=follower_index,
                   user_params=user_params or {})

    def fork(self, follower_index: int) -> "ExecutionContext":
        """Recursive invocation for follower ``follower_index`` (> 0)."""
        if follower_index <= 0:
            raise ValueError("followers must have index > 0")
        return dataclasses.replace(self, follower_index=follower_index)


def sequential(names_fns: Sequence[Tuple[str, Callable]], concurrency: int = 1,
               name: str = "seq") -> ActionManifest:
    """Chain helper: fn_i depends on fn_{i-1}."""
    fns = []
    prev: Tuple[str, ...] = ()
    for n, f in names_fns:
        fns.append(FunctionSpec(n, f, prev))
        prev = (n,)
    return ActionManifest(tuple(fns), concurrency, name)


def parallel(names_fns: Sequence[Tuple[str, Callable]], concurrency: int = 1,
             name: str = "par") -> ActionManifest:
    """All-independent helper (e.g. the 2x ssh-keygen manifest, Table 8)."""
    return ActionManifest(
        tuple(FunctionSpec(n, f) for n, f in names_fns), concurrency, name)
