"""Pallas API compatibility across the jax versions this repo sees.

The kernels target the current Pallas TPU API (``pltpu.CompilerParams``);
older jax releases (<= 0.4.x) expose the same dataclass as
``pltpu.TPUCompilerParams``.  Resolve once here so every kernel tier stays
importable on both, instead of each kernel carrying its own getattr dance.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) or getattr(
    pltpu, "TPUCompilerParams")
