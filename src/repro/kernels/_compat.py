"""Pallas API compatibility across the jax versions this repo sees.

The kernels target the current Pallas TPU API (``pltpu.CompilerParams``);
older jax releases (<= 0.4.x) expose the same dataclass as
``pltpu.TPUCompilerParams``.  Resolve once here so every kernel tier stays
importable on both, instead of each kernel carrying its own getattr dance.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) or getattr(
    pltpu, "TPUCompilerParams")


def interpret_default() -> bool:
    """Whether Pallas calls should default to interpret mode here.

    Compiled Pallas targets the TPU backend; everywhere else (CPU CI
    runners, forced-host device meshes, local dev boxes) the same kernels
    run through the Pallas interpreter so the code path stays exercised.
    Ops with an ``interpret=None`` knob resolve it through this one gate.
    """
    import jax
    return jax.default_backend() != "tpu"
