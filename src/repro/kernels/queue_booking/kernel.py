"""Fused intra-block best-fit booking as a Pallas kernel.

The blocked event-replay substrate (``repro.sim.scan_core``) chunks each
trial's ready-sorted task stream into blocks of B events and carries only
the per-worker free-at vector between blocks.  On accelerators the jnp
form of that loop still round-trips the W-vector and the block's outputs
through HBM once per block; this kernel keeps the whole resolution in
VMEM instead — the free-at vector lives in a VMEM scratch that persists
across the (sequential) block grid dimension, each block's events are
resolved by an in-register ``fori_loop`` over the same fused
best-fit/earliest-free key as ``scan_core.bestfit_book_step``, and one
(1, B) tile per output leaves the core per block.

Grid: (trials, num_blocks), blocks sequential innermost.  One-hot
row/column selects only (no dynamic loads/stores inside the loop) — the
same discipline the jnp engines use, and what the TPU vector unit wants.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams


def _kernel(wf0_ref, r_ref, s_ref, fin_ref, st_ref, wk_ref, wf_out_ref,
            wf_ref, *, num_blocks: int, block: int, W: int):
    ib = pl.program_id(1)

    @pl.when(ib == 0)
    def _init():
        wf_ref[...] = wf0_ref[...]

    r = r_ref[...]                                    # (1, B)
    s = s_ref[...]                                    # (1, B)
    col = lax.broadcasted_iota(jnp.int32, (1, block), 1)
    wcol = lax.broadcasted_iota(jnp.int32, (1, W), 1)

    def body(i, carry):
        wf, fin, st, wk = carry
        sel = col == i
        r_i = jnp.max(jnp.where(sel, r, -jnp.inf))
        s_i = jnp.sum(jnp.where(sel, s, 0.0))
        live = r_i < jnp.inf
        # fused best-fit key: free workers (wf <= r) rank by wf, busy by
        # -wf; -max(key) is the booking-delay floor (scan_core's step)
        key = jnp.where(wf <= r_i, wf, -wf)
        kmax = jnp.max(key)
        w = jnp.argmax(key)
        st_i = jnp.maximum(r_i, -kmax)
        f_i = st_i + s_i
        w_hot = wcol == w
        wf2 = jnp.where(w_hot & live, f_i, wf)
        fin2 = jnp.where(sel, jnp.where(live, f_i, jnp.inf), fin)
        st2 = jnp.where(sel, jnp.where(live, st_i, jnp.inf), st)
        wk2 = jnp.where(sel, jnp.where(live, w.astype(jnp.int32),
                                       jnp.int32(-1)), wk)
        return wf2, fin2, st2, wk2

    wf, fin, st, wk = lax.fori_loop(
        0, block, body,
        (wf_ref[...], jnp.zeros((1, block), jnp.float32),
         jnp.zeros((1, block), jnp.float32),
         jnp.zeros((1, block), jnp.int32)))
    fin_ref[...] = fin
    st_ref[...] = st
    wk_ref[...] = wk
    wf_ref[...] = wf

    @pl.when(ib == num_blocks - 1)
    def _final():
        wf_out_ref[...] = wf


def queue_booking(ready, service, wf0, *, block: int = 64,
                  interpret: bool = False):
    """ready/service: (T, N) ready-sorted event streams (N a multiple of
    ``block``; pad with ready=inf, service=0 — dead events book nothing);
    wf0: (T, W) entry free-at vectors.

    Returns (fin (T, N), start (T, N), worker (T, N) int32, wf (T, W)).
    """
    T, N = ready.shape
    W = wf0.shape[1]
    assert N % block == 0, (N, block)
    nb = N // block

    kernel = functools.partial(_kernel, num_blocks=nb, block=block, W=W)
    fin, st, wk, wf = pl.pallas_call(
        kernel,
        grid=(T, nb),
        in_specs=[
            pl.BlockSpec((1, W), lambda it, ib: (it, 0)),
            pl.BlockSpec((1, block), lambda it, ib: (it, ib)),
            pl.BlockSpec((1, block), lambda it, ib: (it, ib)),
        ],
        out_specs=[
            pl.BlockSpec((1, block), lambda it, ib: (it, ib)),
            pl.BlockSpec((1, block), lambda it, ib: (it, ib)),
            pl.BlockSpec((1, block), lambda it, ib: (it, ib)),
            pl.BlockSpec((1, W), lambda it, ib: (it, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((T, N), jnp.float32),
            jax.ShapeDtypeStruct((T, N), jnp.float32),
            jax.ShapeDtypeStruct((T, N), jnp.int32),
            jax.ShapeDtypeStruct((T, W), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((1, W), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(wf0.astype(jnp.float32), ready.astype(jnp.float32),
      service.astype(jnp.float32))
    return fin, st, wk, wf
