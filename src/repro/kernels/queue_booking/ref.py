"""Oracle for the queue-booking kernel: the sequential best-fit scan.

Delegates to the (separately property-tested) booking step in
:mod:`repro.sim.scan_core` — the exact discipline the closed-loop stock
engine replays (best-fit among free workers, earliest-free fallback,
``ready = inf`` events book nothing) — run one event at a time with the
free-at vector carried through a plain ``lax.scan``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.sim.scan_core import blocked_bestfit_booking


def book_stream_ref(ready, service, wf0):
    """ready/service: (T, N) ready-sorted streams; wf0: (T, W).

    Returns (fin (T, N), start (T, N), worker (T, N) int32, wf (T, W)).
    """
    def one(r, s, w0):
        fin, st, wk = blocked_bestfit_booking(w0, r, s, block=1, full=True)
        live = wk >= 0
        wf = jnp.max(jnp.where((wk[:, None] == jnp.arange(w0.shape[0]))
                               & live[:, None], fin[:, None], w0[None, :]),
                     axis=0)
        return fin, st, wk, wf

    return jax.vmap(one)(ready, service, wf0)
