"""Jitted wrapper for the fused queue-booking kernel.

``interpret=None`` resolves through ``kernels._compat.interpret_default``
(compiled on TPU backends, Pallas interpreter everywhere else) so the
same call site — including ``QueueFlightSim(booking_backend="pallas")``
— runs on CPU CI and on accelerators unchanged.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels._compat import interpret_default
from repro.kernels.queue_booking.kernel import queue_booking
from repro.kernels.queue_booking.ref import book_stream_ref  # noqa: F401


@partial(jax.jit, static_argnames=("block", "interpret"))
def _book_stream(ready, service, wf0, *, block, interpret):
    return queue_booking(ready, service, wf0, block=block,
                         interpret=interpret)


def book_stream(ready, service, wf0, *, block: int = 64, interpret=None):
    """Resolve batched ready-sorted booking streams on the kernel.

    ready/service: (T, N); wf0: (T, W).  N is padded up to a multiple of
    ``block`` with dead events (ready=inf, service=0) and the padding is
    sliced back off.  Returns (fin, start, worker, wf_final).
    """
    if interpret is None:
        interpret = interpret_default()
    T, n = ready.shape
    npad = -(-n // block) * block
    if npad > n:
        pad = npad - n
        ready = jnp.concatenate(
            [ready, jnp.full((T, pad), jnp.inf, ready.dtype)], axis=1)
        service = jnp.concatenate(
            [service, jnp.zeros((T, pad), service.dtype)], axis=1)
    fin, st, wk, wf = _book_stream(ready, service, wf0, block=int(block),
                                   interpret=bool(interpret))
    return fin[:, :n], st[:, :n], wk[:, :n], wf
