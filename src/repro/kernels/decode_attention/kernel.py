"""GQA decode attention (flash-decoding style) as a Pallas TPU kernel.

One new token attends over a long KV cache: the cache is streamed through
VMEM in blocks along the sequence (grid dim 1, sequential), with the online
softmax state for all query heads held in VMEM scratch.  This is the
memory-bound serving hot loop — arithmetic intensity ~ O(Hq/Hkv) — so the
kernel's job is purely to keep the HBM stream dense and skip invalid ring
slots via the position mask.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams

NEG_INF = -2.3819763e38


def _kernel(q_ref, k_ref, v_ref, pos_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, logit_cap: float, rep: int, num_blocks: int):
    ik = pl.program_id(1)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    hq, d = q_ref.shape[1], q_ref.shape[2]
    hkv = hq // rep
    q = q_ref[0].astype(jnp.float32).reshape(hkv, rep, d)
    k = k_ref[0].astype(jnp.float32)                # [bk, hkv, d]
    v = v_ref[0].astype(jnp.float32)
    # s[g, r, bk] = sum_d q[g,r,d] * k[bk,g,d]
    s = jax.lax.dot_general(
        q, k.transpose(1, 2, 0), (((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32) * scale
    if logit_cap:
        s = jnp.tanh(s / logit_cap) * logit_cap
    valid = (pos_ref[...] >= 0)[None, None, :]      # [1,1,bk]
    s = jnp.where(valid, s, NEG_INF)

    s2 = s.reshape(hq, -1)                          # [hq, bk]
    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s2, axis=1, keepdims=True))
    p = jnp.exp(s2 - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
    # acc[g, r, d] += p[g, r, bk] @ v[bk, g, d]
    pv = jax.lax.dot_general(
        p.reshape(hkv, rep, -1), v.transpose(1, 0, 2),
        (((2,), (1,)), ((0,), (0,))), preferred_element_type=jnp.float32)
    acc_ref[...] = acc_ref[...] * corr + pv.reshape(hq, d)
    m_ref[...] = m_new

    @pl.when(ik == num_blocks - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


def decode_attention(q, k, v, kv_pos, *, scale: float | None = None,
                     logit_cap: float = 0.0, block_k: int = 512,
                     interpret: bool = False):
    """q: [B, Hq, D]; k, v: [B, Sk, Hkv, D]; kv_pos: [Sk] -> [B, Hq, D]."""
    b, hq, d = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    rep = hq // hkv
    scale = d ** -0.5 if scale is None else scale
    block_k = min(block_k, sk)
    nk = pl.cdiv(sk, block_k)

    kernel = functools.partial(_kernel, scale=scale, logit_cap=logit_cap,
                               rep=rep, num_blocks=nk)
    out = pl.pallas_call(
        kernel,
        grid=(b, nk),
        in_specs=[
            pl.BlockSpec((1, hq, d), lambda ib, ik: (ib, 0, 0)),
            pl.BlockSpec((1, block_k, hkv, d), lambda ib, ik: (ib, ik, 0, 0)),
            pl.BlockSpec((1, block_k, hkv, d), lambda ib, ik: (ib, ik, 0, 0)),
            pl.BlockSpec((block_k,), lambda ib, ik: (ik,)),
        ],
        out_specs=pl.BlockSpec((1, hq, d), lambda ib, ik: (ib, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((hq, 1), jnp.float32),
            pltpu.VMEM((hq, 1), jnp.float32),
            pltpu.VMEM((hq, d), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v, kv_pos)
    return out
