"""Jitted wrapper for decode attention."""
from functools import partial

import jax

from repro.kernels.decode_attention.kernel import decode_attention
from repro.kernels.decode_attention.ref import decode_attention_ref


@partial(jax.jit, static_argnames=("scale", "logit_cap", "block_k", "interpret"))
def gqa_decode(q, k, v, kv_pos, *, scale=None, logit_cap=0.0, block_k=512,
               interpret=False):
    return decode_attention(q, k, v, kv_pos, scale=scale,
                            logit_cap=logit_cap, block_k=block_k,
                            interpret=interpret)


@partial(jax.jit, static_argnames=("scale", "logit_cap"))
def gqa_decode_reference(q, k, v, kv_pos, *, scale=None, logit_cap=0.0):
    return decode_attention_ref(q, k, v, kv_pos, scale=scale,
                                logit_cap=logit_cap)
