"""Oracle for single-token GQA decode attention over a (possibly ring)
KV cache.  q: [B, Hq, D]; k, v: [B, Sk, Hkv, D]; kv_pos: [Sk] int32 with
-1 marking invalid slots (matches repro.models.transformer ring semantics).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -2.3819763e38


def decode_attention_ref(q, k, v, kv_pos, *, scale: float | None = None,
                         logit_cap: float = 0.0):
    b, hq, d = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    rep = hq // hkv
    scale = d ** -0.5 if scale is None else scale
    kr = jnp.repeat(k, rep, axis=2)              # [B, Sk, Hq, D]
    vr = jnp.repeat(v, rep, axis=2)
    s = jnp.einsum("bhd,bkhd->bhk", q.astype(jnp.float32),
                   kr.astype(jnp.float32)) * scale
    if logit_cap:
        s = jnp.tanh(s / logit_cap) * logit_cap
    s = jnp.where((kv_pos >= 0)[None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhk,bkhd->bhd", p, vr.astype(jnp.float32)).astype(q.dtype)
