"""Jitted wrapper for the max-plus summary-scan kernel.

``interpret=None`` resolves through ``kernels._compat.interpret_default``
(compiled on TPU backends, Pallas interpreter everywhere else) so the
same call site — including ``QueueFlightSim(summary_backend="pallas")``
via ``scan_core.maxplus_prefix_entries`` — runs on CPU CI and on
accelerators unchanged.
"""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels._compat import interpret_default
from repro.kernels.maxplus_scan.kernel import maxplus_scan
from repro.kernels.maxplus_scan.ref import maxplus_scan_ref  # noqa: F401


@partial(jax.jit, static_argnames=("interpret",))
def _maxplus_entries(diag, off, wf0, *, interpret):
    return maxplus_scan(diag, off, wf0, interpret=interpret)


def maxplus_entries(diag, off, wf0, interpret=None):
    """Batched factored-operator prefix: diag/off (T, nb, W), wf0 (T, W).

    Returns ``(entries (T, nb, W), wf_out (T, W))`` — see
    :func:`repro.sim.scan_core.maxplus_prefix_entries` for the contract.
    """
    if interpret is None:
        interpret = interpret_default()
    return _maxplus_entries(diag, off, wf0, interpret=bool(interpret))
