"""Oracle for the max-plus summary-scan kernel.

Delegates to the (separately property-tested) factored-operator algebra in
:mod:`repro.sim.scan_core` — ``maxplus_prefix_entries`` with the
``lax.associative_scan`` backend — vmapped over the trial axis, so kernel
parity here is parity with the exact prefix the log-depth replay consumes.
"""
from __future__ import annotations

import jax

from repro.sim.scan_core import maxplus_prefix_entries


def maxplus_scan_ref(diag, off, wf0):
    """diag/off: (T, nb, W); wf0: (T, W).

    Returns ``(entries (T, nb, W), wf_out (T, W))``.
    """
    def one(d, b, w0):
        return maxplus_prefix_entries(d, b, w0, backend="xla")

    return jax.vmap(one)(diag, off, wf0)
