"""Factored max-plus block-summary prefix scan as a Pallas kernel.

The log-depth event replay (``repro.sim.scan_core``, ``scan="logdepth"``)
summarizes each resolved block of events as a factored W x W max-plus
operator ``(diag, offset)`` over the per-worker free-at vector —
``apply((d, b), wf) = max(wf + d, b)`` — and needs every block's entry
vector, i.e. the exclusive prefix composition of the whole operator tape
applied to the stream's entry vector.  W is tens at most, so one trial's
entire (nb, W) tape fits in VMEM; this kernel resolves it in-core with a
Hillis-Steele doubling scan — log2(nb) fused compose sweeps over the
resident tape, one (1, nb, W) entry tile leaving the core per trial —
instead of round-tripping HBM per compose the way a lowered
``associative_scan`` tree does.

Grid: (trials,), trials parallel.  The compose is the closed form

    compose((d1, b1), (d2, b2)) = (d1 + d2, max(b1 + d2, b2))

("do op1, then op2"); out-of-range shift positions compose with the
identity operator (d = 0, b = -inf).  Static-shape concatenate/slice
shifts only — no dynamic indexing inside the sweep.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels._compat import CompilerParams


def _kernel(d_ref, b_ref, wf0_ref, ent_ref, wf_ref, *, nb: int, W: int):
    d = d_ref[0]                                      # (nb, W)
    b = b_ref[0]                                      # (nb, W)
    # inclusive Hillis-Steele doubling over the block axis: after the
    # sweep row k holds op_0 ∘ ... ∘ op_k
    s = 1
    while s < nb:
        d_sh = jnp.concatenate(
            [jnp.zeros((s, W), d.dtype), d[:nb - s]], axis=0)
        b_sh = jnp.concatenate(
            [jnp.full((s, W), -jnp.inf, b.dtype), b[:nb - s]], axis=0)
        d, b = d_sh + d, jnp.maximum(b_sh + d, b)
        s *= 2
    # entries: row k applies the EXCLUSIVE prefix (rows < k) to wf0;
    # row 0 composes with the identity, i.e. is wf0 itself
    w0 = wf0_ref[...]                                 # (1, W)
    pd = jnp.concatenate([jnp.zeros((1, W), d.dtype), d[:nb - 1]], axis=0)
    pb = jnp.concatenate(
        [jnp.full((1, W), -jnp.inf, b.dtype), b[:nb - 1]], axis=0)
    ent_ref[0] = jnp.maximum(w0 + pd, pb)
    wf_ref[...] = jnp.maximum(w0 + d[nb - 1:nb], b[nb - 1:nb])


def maxplus_scan(diag, off, wf0, *, interpret: bool = False):
    """diag/off: (T, nb, W) factored per-block operators; wf0: (T, W)
    entry vectors.  Returns ``(entries (T, nb, W), wf_out (T, W))`` —
    every block's entry vector plus the whole tape applied to ``wf0``.
    """
    T, nb, W = diag.shape
    kernel = functools.partial(_kernel, nb=nb, W=W)
    ent, wf = pl.pallas_call(
        kernel,
        grid=(T,),
        in_specs=[
            pl.BlockSpec((1, nb, W), lambda t: (t, 0, 0)),
            pl.BlockSpec((1, nb, W), lambda t: (t, 0, 0)),
            pl.BlockSpec((1, W), lambda t: (t, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, nb, W), lambda t: (t, 0, 0)),
            pl.BlockSpec((1, W), lambda t: (t, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((T, nb, W), jnp.float32),
            jax.ShapeDtypeStruct((T, W), jnp.float32),
        ],
        compiler_params=CompilerParams(dimension_semantics=("parallel",)),
        interpret=interpret,
    )(diag.astype(jnp.float32), off.astype(jnp.float32),
      wf0.astype(jnp.float32))
    return ent, wf
