"""Pure-jnp oracle for flash attention (causal, sliding-window, softcap,
GQA).  Layout: q [B, Hq, Sq, D]; k, v [B, Hkv, Sk, D]."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -2.3819763e38


def attention_ref(q, k, v, *, causal: bool = True, window: int = 0,
                  logit_cap: float = 0.0, scale: float | None = None):
    b, hq, sq, d = q.shape
    hkv, sk = k.shape[1], k.shape[2]
    scale = d ** -0.5 if scale is None else scale
    rep = hq // hkv
    if rep > 1:
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if logit_cap:
        s = jnp.tanh(s / logit_cap) * logit_cap
    qpos = jnp.arange(sq)[:, None] + (sk - sq)   # align ends (decode-style)
    kpos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    s = jnp.where(mask[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32)).astype(q.dtype)
