"""Jitted public wrapper for the flash-attention kernel."""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ref import attention_ref


@partial(jax.jit, static_argnames=("causal", "window", "logit_cap", "scale",
                                   "block_q", "block_k", "interpret"))
def mha(q, k, v, *, causal=True, window=0, logit_cap=0.0, scale=None,
        block_q=128, block_k=128, interpret=False):
    return flash_attention(q, k, v, causal=causal, window=window,
                           logit_cap=logit_cap, scale=scale,
                           block_q=block_q, block_k=block_k,
                           interpret=interpret)


@partial(jax.jit, static_argnames=("causal", "window", "logit_cap", "scale"))
def mha_reference(q, k, v, *, causal=True, window=0, logit_cap=0.0, scale=None):
    return attention_ref(q, k, v, causal=causal, window=window,
                         logit_cap=logit_cap, scale=scale)
