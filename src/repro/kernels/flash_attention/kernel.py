"""Flash attention as a Pallas TPU kernel.

TPU adaptation (DESIGN.md §2): the CUDA flash-attention tiling (warps over
128-thread blocks, shared-memory staging) is re-thought for the TPU memory
hierarchy — HBM -> VMEM block staging driven by BlockSpecs, MXU-aligned
(block_q x block_k) score tiles, online-softmax state (m, l, acc) carried in
VMEM scratch across the kv grid dimension, and causal/window block SKIPPING
expressed through the grid index map (fully-masked tiles never leave HBM).

Grid: (batch*heads, num_q_blocks, num_kv_blocks); kv is the innermost
(sequential) dimension so scratch accumulates across it.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams

NEG_INF = -2.3819763e38


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, causal: bool, window: int, logit_cap: float,
            block_q: int, block_k: int, num_kv_blocks: int, sk: int):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)                 # [bq, d]
    k = k_ref[0].astype(jnp.float32)                 # [bk, d]
    v = v_ref[0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if logit_cap:
        s = jnp.tanh(s / logit_cap) * logit_cap

    qpos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    kpos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = kpos < sk
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                              # [bq, 1]
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)                           # [bq, bk]
    corr = jnp.exp(m_prev - m_new)                   # [bq, 1]
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ik == num_kv_blocks - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    logit_cap: float = 0.0, scale: float | None = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = False):
    """q: [B, Hq, Sq, D]; k, v: [B, Hkv, Sk, D] -> [B, Hq, Sq, D].

    GQA is handled by the k/v index maps (q head h reads kv head
    h // (Hq//Hkv)) — no materialised repeat.
    """
    b, hq, sq, d = q.shape
    hkv, sk = k.shape[1], k.shape[2]
    rep = hq // hkv
    scale = d ** -0.5 if scale is None else scale
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    nq = pl.cdiv(sq, block_q)
    nk = pl.cdiv(sk, block_k)

    qr = q.reshape(b * hq, sq, d)
    kr = k.reshape(b * hkv, sk, d)
    vr = v.reshape(b * hkv, sk, d)

    def q_map(bh, iq, ik):
        return (bh, iq, 0)

    def kv_map(bh, iq, ik):
        return ((bh // hq) * hkv + (bh % hq) // rep, ik, 0)

    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, window=window,
        logit_cap=logit_cap, block_q=block_q, block_k=block_k,
        num_kv_blocks=nk, sk=sk)

    out = pl.pallas_call(
        kernel,
        grid=(b * hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, d), q_map),
            pl.BlockSpec((1, block_k, d), kv_map),
            pl.BlockSpec((1, block_k, d), kv_map),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), q_map),
        out_shape=jax.ShapeDtypeStruct((b * hq, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(b, hq, sq, d)
