"""Oracle for the chunked SSD scan kernel: delegates to the (already
validated) pure-jnp implementation in repro.models.mamba2."""
from repro.models.mamba2 import ssd_chunked  # noqa: F401


def ssd_ref(x, dt, A, B, C, *, chunk: int):
    return ssd_chunked(x, dt, A, B, C, chunk=chunk)
