"""Jitted wrapper for the SSD chunked-scan kernel."""
from functools import partial

import jax

from repro.kernels.ssd_scan.kernel import ssd_scan
from repro.kernels.ssd_scan.ref import ssd_ref


@partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd(x, dt, A, B, C, *, chunk=256, interpret=False):
    return ssd_scan(x, dt, A, B, C, chunk=chunk, interpret=interpret)


@partial(jax.jit, static_argnames=("chunk",))
def ssd_reference(x, dt, A, B, C, *, chunk=256):
    return ssd_ref(x, dt, A, B, C, chunk=chunk)
