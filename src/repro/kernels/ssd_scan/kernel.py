"""Mamba2 SSD chunked scan as a Pallas TPU kernel.

TPU adaptation (DESIGN.md §2): the GPU SSD implementation leans on warp
shuffles and shared-memory chunk staging; here the chunk loop is the
innermost (sequential) grid dimension, the inter-chunk SSM state [P, N]
lives in VMEM scratch, and the intra-chunk work is expressed as three
MXU matmuls per (batch, head, chunk): CB^T [Q,Q], (CB*L)@dtx [Q,P], and
the state outer product dtx^T@(decay*B) [P,N].

Grid: (B, H, num_chunks), chunk sequential.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, st_out_ref, state_ref,
            *, num_chunks: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0, :, 0].astype(jnp.float32)           # [Q, P]
    dt = dt_ref[0, :, 0].astype(jnp.float32)         # [Q]
    A = a_ref[0]                                     # scalar (negative)
    Bm = b_ref[0, :, 0].astype(jnp.float32)          # [Q, N]
    Cm = c_ref[0, :, 0].astype(jnp.float32)          # [Q, N]

    a = dt * A                                       # [Q] log-decay
    cum = jnp.cumsum(a)                              # [Q]
    q = x.shape[0]
    seg = cum[:, None] - cum[None, :]                # segsum
    tri = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    L = jnp.where(tri, jnp.exp(seg), 0.0)            # [Q, Q]

    cb = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # [Q,Q]
    dtx = x * dt[:, None]                            # [Q, P]
    y = jax.lax.dot_general(cb * L, dtx, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)   # [Q,P]

    # inter-chunk contribution from the carried state
    state = state_ref[...]                           # [P, N]
    y += jnp.exp(cum)[:, None] * jax.lax.dot_general(
        Cm, state, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)          # Cm @ state^T -> [Q,P]

    # state update: decay + chunk contribution
    decay_to_end = jnp.exp(cum[-1] - cum)            # [Q]
    st_new = state * jnp.exp(cum[-1]) + jax.lax.dot_general(
        dtx, Bm * decay_to_end[:, None], (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)          # [P, N]
    state_ref[...] = st_new

    y_ref[0, :, 0] = y.astype(y_ref.dtype)

    @pl.when(ic == num_chunks - 1)
    def _final():
        st_out_ref[0, 0] = st_new.astype(st_out_ref.dtype)


def ssd_scan(x, dt, A, B, C, *, chunk: int = 256, interpret: bool = False):
    """x: [b,s,h,p]; dt: [b,s,h]; A: [h]; B,C: [b,s,g,n] (h % g == 0).

    Returns (y [b,s,h,p], final_state [b,h,p,n]).
    """
    b, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    q = min(chunk, s)
    assert s % q == 0
    nc = s // q
    rep = h // g

    kernel = functools.partial(_kernel, num_chunks=nc)
    y, st = pl.pallas_call(
        kernel,
        grid=(b, h, nc),
        in_specs=[
            pl.BlockSpec((1, q, 1, p), lambda ib, ih, ic: (ib, ic, ih, 0)),
            pl.BlockSpec((1, q, 1), lambda ib, ih, ic: (ib, ic, ih)),
            pl.BlockSpec((1,), lambda ib, ih, ic: (ih,)),
            pl.BlockSpec((1, q, 1, n), lambda ib, ih, ic, rep=rep:
                         (ib, ic, ih // rep, 0)),
            pl.BlockSpec((1, q, 1, n), lambda ib, ih, ic, rep=rep:
                         (ib, ic, ih // rep, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, q, 1, p), lambda ib, ih, ic: (ib, ic, ih, 0)),
            pl.BlockSpec((1, 1, p, n), lambda ib, ih, ic: (ib, ih, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, s, h, p), jnp.float32),
            jax.ShapeDtypeStruct((b, h, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x.astype(jnp.float32), dt.astype(jnp.float32), A.astype(jnp.float32),
      B.astype(jnp.float32), C.astype(jnp.float32))
    return y, st
