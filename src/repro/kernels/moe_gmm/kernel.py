"""Capacity-batched expert matmul (MoE grouped GEMM) as a Pallas kernel.

The EP dispatch (repro.models.moe) produces dense [E, C, D] capacity
buffers; expert compute is then an expert-batched GEMM.  Blocks are MXU
aligned, the contraction dim is the innermost (sequential) grid dim with a
f32 VMEM accumulator, and each (expert, row-block, col-block) tile streams
A and W blocks from HBM exactly once.

Grid: (E, C/bc, F/bf, D/bd).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams


def _kernel(a_ref, w_ref, o_ref, acc_ref, *, num_k: int):
    kd = pl.program_id(3)

    @pl.when(kd == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[0].astype(jnp.float32)        # [bc, bd]
    w = w_ref[0].astype(jnp.float32)        # [bd, bf]
    acc_ref[...] += jax.lax.dot_general(
        a, w, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(kd == num_k - 1)
    def _done():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


def expert_matmul(buf, w, *, block_c: int = 128, block_f: int = 128,
                  block_d: int = 256, interpret: bool = False):
    """buf: [E, C, D]; w: [E, D, F] -> [E, C, F]."""
    e, c, d = buf.shape
    f = w.shape[2]
    block_c = min(block_c, c)
    block_f = min(block_f, f)
    block_d = min(block_d, d)
    nc, nf, nd = pl.cdiv(c, block_c), pl.cdiv(f, block_f), pl.cdiv(d, block_d)

    kernel = functools.partial(_kernel, num_k=nd)
    return pl.pallas_call(
        kernel,
        grid=(e, nc, nf, nd),
        in_specs=[
            pl.BlockSpec((1, block_c, block_d),
                         lambda ie, ic, jf, kd: (ie, ic, kd)),
            pl.BlockSpec((1, block_d, block_f),
                         lambda ie, ic, jf, kd: (ie, kd, jf)),
        ],
        out_specs=pl.BlockSpec((1, block_c, block_f),
                               lambda ie, ic, jf, kd: (ie, ic, jf)),
        out_shape=jax.ShapeDtypeStruct((e, c, f), buf.dtype),
        scratch_shapes=[pltpu.VMEM((block_c, block_f), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(buf, w)
