"""Oracle for the capacity-batched expert matmul (MoE grouped GEMM)."""
import jax.numpy as jnp


def expert_matmul_ref(buf, w):
    """buf: [E, C, D]; w: [E, D, F] -> [E, C, F] (f32 accumulation)."""
    return jnp.einsum("ecd,edf->ecf", buf.astype(jnp.float32),
                      w.astype(jnp.float32)).astype(buf.dtype)
