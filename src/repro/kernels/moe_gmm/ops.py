"""Jitted wrapper for the expert-batched GEMM."""
from functools import partial

import jax

from repro.kernels.moe_gmm.kernel import expert_matmul
from repro.kernels.moe_gmm.ref import expert_matmul_ref


@partial(jax.jit, static_argnames=("block_c", "block_f", "block_d", "interpret"))
def gmm(buf, w, *, block_c=128, block_f=128, block_d=256, interpret=False):
    return expert_matmul(buf, w, block_c=block_c, block_f=block_f,
                         block_d=block_d, interpret=interpret)


gmm_reference = jax.jit(expert_matmul_ref)
