"""Unified LM covering all assigned families.

One parameter pytree + three pure entry points per architecture:

- ``loss_fn(params, batch)``             (train_4k)
- ``prefill(params, inputs)``            (prefill_32k) -> (last_logits, cache)
- ``decode_step(params, cache, inputs)`` (decode_32k / long_500k)

Families: dense / moe (interleaved or every-layer) / ssm (mamba2) /
hybrid (mamba2 + one shared attention block) / vlm & audio backbones
(embedding inputs) / encoder-decoder (seamless).

``constrain(tensor, role)`` is an injection point for sharding constraints —
identity by default so smoke tests run un-meshed on CPU.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import mamba2 as m2
from repro.models.layers import (
    apply_mrope,
    apply_rope,
    attention_blockwise,
    attention_full,
    attention_sliding_blocked,
    mlp_block,
    rms_norm,
    softcap,
)
from repro.models.moe import init_moe_params, moe_block

Constrain = Callable[[jnp.ndarray, str], jnp.ndarray]
_ID: Constrain = lambda t, role: t

BLOCKWISE_THRESHOLD = 8192   # prefill longer than this uses flash-style scan
KV_CHUNK = 1024


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------

def _init_attn(key, cfg: ModelConfig, dtype):
    d, hd = cfg.d_model, cfg.resolved_head_dim
    hq, hkv = cfg.num_heads, cfg.num_kv_heads
    ks = jax.random.split(key, 4)
    s = 0.02
    return {
        "wq": jax.random.normal(ks[0], (d, hq * hd), dtype) * s,
        "wk": jax.random.normal(ks[1], (d, hkv * hd), dtype) * s,
        "wv": jax.random.normal(ks[2], (d, hkv * hd), dtype) * s,
        "wo": jax.random.normal(ks[3], (hq * hd, d), dtype) * s,
    }


def _init_mlp(key, d, ff, dtype):
    ks = jax.random.split(key, 3)
    s = 0.02
    return {
        "w_gate": jax.random.normal(ks[0], (d, ff), dtype) * s,
        "w_up": jax.random.normal(ks[1], (d, ff), dtype) * s,
        "w_down": jax.random.normal(ks[2], (ff, d), dtype) * s,
    }


def _init_block(key, cfg: ModelConfig, i: int, dtype):
    kind = cfg.layer_kind(i)
    ks = jax.random.split(key, 3)
    p: Dict[str, Any] = {"ln1": jnp.zeros((cfg.d_model,), jnp.float32)}
    if kind == "ssm":
        p["ssm"] = m2.init_mamba2_params(ks[0], cfg.d_model, cfg.ssm, dtype)
        return p
    p["attn"] = _init_attn(ks[0], cfg, dtype)
    p["ln2"] = jnp.zeros((cfg.d_model,), jnp.float32)
    if cfg.is_moe_layer(i):
        p["moe"] = init_moe_params(ks[1], cfg.d_model, cfg.moe, dtype)
    else:
        p["mlp"] = _init_mlp(ks[1], cfg.d_model, cfg.d_ff, dtype)
    return p


def _init_enc_block(key, cfg: ModelConfig, dtype):
    ks = jax.random.split(key, 2)
    return {
        "ln1": jnp.zeros((cfg.d_model,), jnp.float32),
        "attn": _init_attn(ks[0], cfg, dtype),
        "ln2": jnp.zeros((cfg.d_model,), jnp.float32),
        "mlp": _init_mlp(ks[1], cfg.d_model, cfg.d_ff, dtype),
    }


def _init_dec_block(key, cfg: ModelConfig, dtype):
    ks = jax.random.split(key, 3)
    p = _init_enc_block(ks[0], cfg, dtype)
    p["ln_cross"] = jnp.zeros((cfg.d_model,), jnp.float32)
    p["cross"] = _init_attn(ks[1], cfg, dtype)
    return p


def init_params(cfg: ModelConfig, key) -> Dict[str, Any]:
    dtype = jnp.dtype(cfg.dtype)
    keys = jax.random.split(key, cfg.num_layers + 8)
    params: Dict[str, Any] = {
        "embed": jax.random.normal(keys[0], (cfg.vocab_size, cfg.d_model), dtype) * 0.02,
        "final_norm": jnp.zeros((cfg.d_model,), jnp.float32),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = jax.random.normal(
            keys[1], (cfg.d_model, cfg.vocab_size), dtype) * 0.02
    if cfg.is_encoder_decoder:
        ek = jax.random.split(keys[2], cfg.num_encoder_layers)
        params["encoder"] = {
            "layers": [_init_enc_block(k, cfg, dtype) for k in ek],
            "final_norm": jnp.zeros((cfg.d_model,), jnp.float32),
        }
        params["layers"] = [
            _init_dec_block(keys[3 + i], cfg, dtype) for i in range(cfg.num_layers)]
        return params
    params["layers"] = [
        _init_block(keys[3 + i], cfg, i, dtype) for i in range(cfg.num_layers)]
    if cfg.family == "hybrid" and cfg.hybrid_attn_every:
        params["shared_block"] = _init_enc_block(keys[2], cfg, dtype)
    return params


# --------------------------------------------------------------------------
# attention block (train / prefill / decode) with cache handling
# --------------------------------------------------------------------------

def _attn_scale(cfg: ModelConfig) -> float:
    base = cfg.query_pre_attn_scalar or cfg.resolved_head_dim
    return float(base) ** -0.5


def _repeat_kv_full(k, hq: int):
    b, s, hkv, hd = k.shape
    if hkv == hq:
        return k
    return jnp.broadcast_to(k[:, :, :, None],
                            (b, s, hkv, hq // hkv, hd)).reshape(b, s, hq, hd)


def _pad_heads(t, target: int):
    pad = target - t.shape[2]
    return jnp.pad(t, ((0, 0), (0, 0), (0, pad), (0, 0))) if pad else t


def _project_qkv(x, p, cfg: ModelConfig, positions, constrain: Constrain):
    b, s, _ = x.shape
    hd = cfg.resolved_head_dim
    q = (x @ p["wq"]).reshape(b, s, cfg.num_heads, hd)
    k = (x @ p["wk"]).reshape(b, s, cfg.num_kv_heads, hd)
    v = (x @ p["wv"]).reshape(b, s, cfg.num_kv_heads, hd)
    q = constrain(q, "act_heads")
    k = constrain(k, "act_kv_heads")
    v = constrain(v, "act_kv_heads")
    if positions is not None:
        if cfg.mrope:
            q = apply_mrope(q, positions, cfg.rope_theta, cfg.mrope_sections)
            k = apply_mrope(k, positions, cfg.rope_theta, cfg.mrope_sections)
        else:
            q = apply_rope(q, positions, cfg.rope_theta)
            k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def attention_block(x, p, cfg: ModelConfig, *, kind: str, mode: str,
                    positions, cache=None, constrain: Constrain = _ID,
                    cross_kv=None):
    """Full attention sublayer incl. cache read/write.  x: [B,S,D]."""
    b, s, _ = x.shape
    hd = cfg.resolved_head_dim
    scale = _attn_scale(cfg)
    cap = cfg.attn_logit_softcap
    local = kind == "local_attn"
    window = cfg.window_size if local else 0

    if cross_kv is not None:                                   # enc-dec cross attention
        q = (x @ p["wq"]).reshape(b, s, cfg.num_heads, hd)
        k, v = cross_kv
        out = attention_full(q, k, v, causal=False, scale=scale, logit_cap=cap)
        return out.reshape(b, s, -1) @ p["wo"], cache

    q, k, v = _project_qkv(x, p, cfg, positions, constrain)

    if mode == "decode":
        # cache: {"k": [B, C, hkv, hd], "v": ..., } write at index (ring for local)
        idx = cache["index"]                                   # scalar int32
        cache_len = cache["k"].shape[1]
        slot = (idx % cache_len) if local else jnp.minimum(idx, cache_len - 1)
        kc = jax.lax.dynamic_update_slice(cache["k"], k, (0, slot, 0, 0))
        vc = jax.lax.dynamic_update_slice(cache["v"], v, (0, slot, 0, 0))
        if local:
            # ring cache: slot s holds absolute position idx - ((idx - s) mod C)
            slots = jnp.arange(cache_len)
            kv_pos = idx - ((idx - slots) % cache_len)
            valid = (kv_pos >= 0) & (kv_pos > idx - window) & (kv_pos <= idx)
            scores_kpos = jnp.where(valid, kv_pos, -1)
            out = _decode_attention(q, kc, vc, scores_kpos, idx, scale, cap)
        else:
            kv_pos = jnp.arange(cache_len)
            scores_kpos = jnp.where(kv_pos <= idx, kv_pos, -1)
            out = _decode_attention(q, kc, vc, scores_kpos, idx, scale, cap)
        new_cache = dict(cache, k=kc, v=vc)
        return out.reshape(b, s, -1) @ p["wo"], new_cache

    # train / prefill over the full sequence
    hq = cfg.num_heads
    padded = cfg.pad_heads and cfg.pad_heads > hq
    if padded:
        # pre-repeat KV to full query heads, zero-pad the head dim to a
        # model-axis multiple, and shard heads — padded heads only ever
        # interact with padded heads, and their outputs are sliced away.
        k = _pad_heads(_repeat_kv_full(k, hq), cfg.pad_heads)
        v = _pad_heads(_repeat_kv_full(v, hq), cfg.pad_heads)
        q = _pad_heads(q, cfg.pad_heads)
        q = constrain(q, "act_heads")
        k = constrain(k, "act_heads")
        v = constrain(v, "act_heads")
    if local and s > window:
        out = attention_sliding_blocked(q, k, v, window=window,
                                        logit_cap=cap, scale=scale)
    elif s > BLOCKWISE_THRESHOLD:
        out = attention_blockwise(q, k, v, causal=True, logit_cap=cap,
                                  scale=scale, chunk=KV_CHUNK)
    else:
        out = attention_full(q, k, v, causal=True, window=window,
                             logit_cap=cap, scale=scale)
    if padded:
        out = out[:, :, :hq]
    out = constrain(out, "act_heads")
    new_cache = cache
    if mode == "prefill":
        cache_len = cache["k"].shape[1]
        if local:
            keep = min(window, s)
            ks_, vs_ = k[:, -keep:], v[:, -keep:]
            start = (s - keep) % cache_len
            kc = _ring_write(cache["k"], ks_, start)
            vc = _ring_write(cache["v"], vs_, start)
        else:
            kc = jax.lax.dynamic_update_slice(cache["k"], k, (0, 0, 0, 0))
            vc = jax.lax.dynamic_update_slice(cache["v"], v, (0, 0, 0, 0))
        new_cache = dict(cache, k=kc, v=vc)
    return out.reshape(b, s, -1) @ p["wo"], new_cache


def _ring_write(buf, vals, start):
    """Write vals into ring buffer starting at ``start`` (static shapes)."""
    c = buf.shape[1]
    n = vals.shape[1]
    if n == c and isinstance(start, int) and start == 0:
        return vals
    idx = (jnp.arange(n) + start) % c
    return buf.at[:, idx].set(vals)


def _decode_attention(q, kc, vc, kv_pos, idx, scale, cap):
    """Single-token attention over a cache with explicit key positions.

    q: [B,1,Hq,hd]; kc/vc: [B,C,hkv,hd]; kv_pos: [C] (-1 = invalid).

    GQA is computed as a grouped einsum — NEVER by materialising a repeated
    KV: with the cache sharded on head_dim (kv-nondivisible archs) the
    broadcast_to+reshape formulation forces GSPMD into involuntary full
    rematerialisation of the whole cache (measured: 1.9 s collective term
    for gemma2-9b decode_32k; grouped einsum: ~0.03 s).
    """
    b, _, hq, hd = q.shape
    hkv = kc.shape[2]
    rep = hq // hkv
    qg = q.reshape(b, hkv, rep, hd)
    s = jnp.einsum("bgrd,bsgd->bgrs", qg, kc).astype(jnp.float32) * scale
    s = softcap(s, cap)
    s = jnp.where((kv_pos >= 0)[None, None, None, :], s, -2.3819763e38)
    pr = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    out = jnp.einsum("bgrs,bsgd->bgrd", pr, vc)
    return out.reshape(b, 1, hq, hd)


# --------------------------------------------------------------------------
# block and stack
# --------------------------------------------------------------------------

def _block_apply(x, p, cfg: ModelConfig, i: int, *, mode, positions,
                 cache=None, constrain: Constrain = _ID, ep=None):
    kind = cfg.layer_kind(i)
    aux = jnp.zeros((), jnp.float32)
    if kind == "ssm":
        h = rms_norm(x, p["ln1"], cfg.norm_eps)
        y, cache = m2.mamba2_block(h, p["ssm"], cfg.ssm, mode=mode, cache=cache,
                                   constrain=constrain)
        return x + y, cache, aux
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    y, cache = attention_block(h, p["attn"], cfg, kind=kind, mode=mode,
                               positions=positions, cache=cache,
                               constrain=constrain)
    x = x + y
    h = rms_norm(x, p["ln2"], cfg.norm_eps)
    if "moe" in p:
        y, aux = moe_block(h, p["moe"], cfg.moe, cfg.mlp_variant,
                           constrain=constrain, ep=ep)
    else:
        y = mlp_block(h, p["mlp"], cfg.mlp_variant)
        y = constrain(y, "act_ff_out")
    return x + y, cache, aux


def _shared_block_apply(x, p, cfg: ModelConfig, *, mode, positions, cache,
                        constrain: Constrain = _ID):
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    y, cache = attention_block(h, p["attn"], cfg, kind="attn", mode=mode,
                               positions=positions, cache=cache,
                               constrain=constrain)
    x = x + y
    h = rms_norm(x, p["ln2"], cfg.norm_eps)
    x = x + mlp_block(h, p["mlp"], cfg.mlp_variant)
    return x, cache


def apply_stack(params, cfg: ModelConfig, x, *, mode, positions,
                caches=None, constrain: Constrain = _ID, enc_out=None,
                remat: bool = False, ep=None, remat_policy=None):
    """x: [B,S,D] embeddings.  Returns (hidden, new_caches, aux_loss)."""
    new_caches: Dict[str, Any] = {}
    aux_total = jnp.zeros((), jnp.float32)
    shared_i = 0
    use_ckpt = remat and mode == "train"
    for i in range(cfg.num_layers):
        p = params["layers"][i]
        c = caches.get(f"layer_{i}") if caches else None
        if cfg.is_encoder_decoder:
            x, c, aux = _decoder_block_apply(
                x, p, cfg, mode=mode, positions=positions, cache=c,
                constrain=constrain, enc_out=enc_out)
        elif use_ckpt:
            fn = lambda x_, p_, pos_, i_=i: _block_apply(
                x_, p_, cfg, i_, mode="train", positions=pos_, cache=None,
                constrain=constrain, ep=ep)
            x, c, aux = jax.checkpoint(fn, policy=remat_policy)(x, p, positions)
        else:
            x, c, aux = _block_apply(x, p, cfg, i, mode=mode,
                                     positions=positions, cache=c,
                                     constrain=constrain, ep=ep)
        aux_total = aux_total + aux
        if c is not None:
            new_caches[f"layer_{i}"] = c
        x = constrain(x, "act_resid")
        if (cfg.family == "hybrid" and cfg.hybrid_attn_every
                and (i + 1) % cfg.hybrid_attn_every == 0):
            sc = caches.get(f"shared_{shared_i}") if caches else None
            x, sc = _shared_block_apply(
                x, params["shared_block"], cfg, mode=mode,
                positions=positions, cache=sc, constrain=constrain)
            if sc is not None:
                new_caches[f"shared_{shared_i}"] = sc
            shared_i += 1
    return x, new_caches, aux_total


def _decoder_block_apply(x, p, cfg, *, mode, positions, cache, constrain,
                         enc_out):
    aux = jnp.zeros((), jnp.float32)
    self_cache = cache.get("self") if cache else None
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    y, self_cache = attention_block(h, p["attn"], cfg, kind="attn", mode=mode,
                                    positions=positions, cache=self_cache,
                                    constrain=constrain)
    x = x + y
    # cross attention: K/V from encoder output (precomputed once in decode)
    h = rms_norm(x, p["ln_cross"], cfg.norm_eps)
    if cache and "cross_k" in cache:
        ck, cv = cache["cross_k"], cache["cross_v"]
    else:
        b, se, _ = enc_out.shape
        hd = cfg.resolved_head_dim
        ck = (enc_out @ p["cross"]["wk"]).reshape(b, se, cfg.num_kv_heads, hd)
        cv = (enc_out @ p["cross"]["wv"]).reshape(b, se, cfg.num_kv_heads, hd)
    y, _ = attention_block(h, p["cross"], cfg, kind="attn", mode=mode,
                           positions=None, cache=None, constrain=constrain,
                           cross_kv=(ck, cv))
    x = x + y
    h = rms_norm(x, p["ln2"], cfg.norm_eps)
    x = x + mlp_block(h, p["mlp"], cfg.mlp_variant)
    new_cache = None
    if self_cache is not None:
        new_cache = {"self": self_cache, "cross_k": ck, "cross_v": cv}
    return x, new_cache, aux


def encode(params, cfg: ModelConfig, enc_emb, constrain: Constrain = _ID):
    """Bidirectional encoder over precomputed frame embeddings."""
    x = enc_emb
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    for p in params["encoder"]["layers"]:
        h = rms_norm(x, p["ln1"], cfg.norm_eps)
        q, k, v = _project_qkv(h, p["attn"], cfg, positions, constrain)
        out = attention_full(q, k, v, causal=False, scale=_attn_scale(cfg))
        x = x + out.reshape(b, s, -1) @ p["attn"]["wo"]
        h = rms_norm(x, p["ln2"], cfg.norm_eps)
        x = x + mlp_block(h, p["mlp"], cfg.mlp_variant)
        x = constrain(x, "act_resid")
    return rms_norm(x, params["encoder"]["final_norm"], cfg.norm_eps)


# --------------------------------------------------------------------------
# heads / losses / entry points
# --------------------------------------------------------------------------

def _embed(params, cfg: ModelConfig, tokens_or_emb):
    if cfg.embedding_inputs and tokens_or_emb.ndim == 3:
        return tokens_or_emb
    return params["embed"][tokens_or_emb].astype(jnp.dtype(cfg.dtype))


def _logits(params, cfg: ModelConfig, h, constrain: Constrain = _ID):
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = h @ head
    if cfg.final_logit_softcap:
        logits = softcap(logits.astype(jnp.float32),
                         cfg.final_logit_softcap).astype(h.dtype)
    return constrain(logits, "logits")


def cross_entropy(logits, labels, vocab: int):
    """logits: [B,S,V] (bf16, possibly vocab-sharded); labels: [B,S] int32.

    Written so XLA fuses the [B,S,V]-sized intermediates into reductions:
    no one-hot / f32 logits buffer is ever materialised (the peak-memory
    killer at V=256k) — verified via memory_analysis in the dry-run.
    """
    m = jax.lax.stop_gradient(jnp.max(logits, axis=-1, keepdims=True))
    shifted = (logits - m).astype(jnp.float32)
    sumexp = jnp.sum(jnp.exp(shifted), axis=-1)
    lse = jnp.log(sumexp) + m[..., 0].astype(jnp.float32)
    eq = labels[..., None] == jax.lax.broadcasted_iota(
        jnp.int32, logits.shape, len(logits.shape) - 1)
    picked = jnp.sum(jnp.where(eq, shifted, 0.0), axis=-1)
    picked = picked + m[..., 0].astype(jnp.float32)
    return lse - picked                                    # per-token [B,S]


def loss_fn(params, cfg: ModelConfig, batch, constrain: Constrain = _ID,
            remat: bool = False, ep=None, remat_policy=None):
    """batch: {"tokens" | "embeddings", "labels", ["positions"], ["enc_emb"]}"""
    if cfg.is_encoder_decoder:
        enc_out = encode(params, cfg, batch["enc_emb"], constrain)
    else:
        enc_out = None
    x = _embed(params, cfg, batch.get("tokens", batch.get("embeddings")))
    b, s = x.shape[0], x.shape[1]
    positions = batch.get("positions")
    if positions is None and not cfg.attention_free:
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
        if cfg.mrope:
            positions = jnp.broadcast_to(positions[None], (3, b, s))
    h, _, aux = apply_stack(params, cfg, x, mode="train", positions=positions,
                            constrain=constrain, enc_out=enc_out, remat=remat,
                            ep=ep, remat_policy=remat_policy)
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = _logits(params, cfg, h, constrain)
    per_tok = cross_entropy(logits, batch["labels"], cfg.vocab_size)
    w = batch.get("loss_weight")                           # [B] per-sample
    if w is not None:
        # Raptor redundant-DP: zero weight == dropped/preempted flight
        # member; mean renormalises over the surviving samples.
        wt = w.astype(jnp.float32)[:, None]
        ce = (per_tok * wt).sum() / jnp.maximum(
            wt.sum() * per_tok.shape[1], 1.0)
    else:
        ce = per_tok.mean()
    return ce + 0.01 * aux, {"ce": ce, "aux": aux}


def init_cache(cfg: ModelConfig, batch: int, max_len: int, enc_len: int = 0):
    """Preallocated decode cache pytree (all zeros)."""
    dtype = jnp.dtype(cfg.dtype)
    hd = cfg.resolved_head_dim
    caches: Dict[str, Any] = {"index": jnp.zeros((), jnp.int32)}

    def kv(c_len):
        return {"k": jnp.zeros((batch, c_len, cfg.num_kv_heads, hd), dtype),
                "v": jnp.zeros((batch, c_len, cfg.num_kv_heads, hd), dtype)}

    for i in range(cfg.num_layers):
        kind = cfg.layer_kind(i)
        if kind == "ssm":
            caches[f"layer_{i}"] = m2.init_ssm_cache(batch, cfg.d_model, cfg.ssm, dtype)
        elif cfg.is_encoder_decoder:
            caches[f"layer_{i}"] = {
                "self": kv(max_len),
                "cross_k": jnp.zeros((batch, enc_len, cfg.num_kv_heads, hd), dtype),
                "cross_v": jnp.zeros((batch, enc_len, cfg.num_kv_heads, hd), dtype),
            }
        else:
            c_len = min(cfg.window_size, max_len) if kind == "local_attn" else max_len
            caches[f"layer_{i}"] = kv(c_len)
    if cfg.family == "hybrid" and cfg.hybrid_attn_every:
        for j in range(cfg.num_layers // cfg.hybrid_attn_every):
            caches[f"shared_{j}"] = kv(max_len)
    return caches


def prefill(params, cfg: ModelConfig, batch, max_len: int,
            constrain: Constrain = _ID, ep=None):
    """Run the full prompt, return (last-position logits, filled cache)."""
    if cfg.is_encoder_decoder:
        enc_out = encode(params, cfg, batch["enc_emb"], constrain)
    else:
        enc_out = None
    x = _embed(params, cfg, batch.get("tokens", batch.get("embeddings")))
    b, s = x.shape[0], x.shape[1]
    positions = batch.get("positions")
    if positions is None and not cfg.attention_free:
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
        if cfg.mrope:
            positions = jnp.broadcast_to(positions[None], (3, b, s))
    caches = init_cache(cfg, b, max_len,
                        enc_len=enc_out.shape[1] if enc_out is not None else 0)
    h, new_caches, _ = apply_stack(params, cfg, x, mode="prefill",
                                   positions=positions, caches=caches,
                                   constrain=constrain, enc_out=enc_out, ep=ep)
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = _logits(params, cfg, h[:, -1:], constrain)
    new_caches["index"] = jnp.full((), s, jnp.int32)
    return logits[:, 0], new_caches


def decode_step(params, cfg: ModelConfig, caches, tokens,
                constrain: Constrain = _ID, enc_out=None, ep=None):
    """One decode step.  tokens: [B,1] int32 (or [B,1,D] embeddings)."""
    x = _embed(params, cfg, tokens)
    b = x.shape[0]
    idx = caches["index"]
    if cfg.mrope:
        positions = jnp.broadcast_to(idx[None, None, None], (3, b, 1)).astype(jnp.int32)
    else:
        positions = jnp.broadcast_to(idx[None, None], (b, 1)).astype(jnp.int32)
    if cfg.attention_free:
        positions = None
    # thread the index into per-layer caches for ring addressing
    run_caches = {k: (dict(v, index=idx) if isinstance(v, dict) and "k" in v
                      else ({**v, "self": dict(v["self"], index=idx)}
                            if isinstance(v, dict) and "self" in v else v))
                  for k, v in caches.items() if k != "index"}
    h, new_caches, _ = apply_stack(params, cfg, x, mode="decode",
                                   positions=positions, caches=run_caches,
                                   constrain=constrain, enc_out=enc_out, ep=ep)
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = _logits(params, cfg, h, constrain)
    out_caches = {}
    for k, v in new_caches.items():
        if isinstance(v, dict) and "index" in v:
            v = {kk: vv for kk, vv in v.items() if kk != "index"}
        elif isinstance(v, dict) and "self" in v and "index" in v["self"]:
            v = dict(v, self={kk: vv for kk, vv in v["self"].items() if kk != "index"})
        out_caches[k] = v
    out_caches["index"] = idx + 1
    return logits[:, 0], out_caches
