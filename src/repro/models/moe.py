"""Mixture-of-Experts block.

Two implementations, same math:

- ``moe_block_global``: capacity-based dispatch in pure global-view jnp.
  Used un-meshed (CPU smoke tests / tiny models).  GSPMD materialises
  [k*T, D] slot tensors for this formulation, so it is NOT used on the
  production mesh (measured: 48 GiB/device buffers for granite train_4k).

- ``moe_block_ep``: production path.  shard_map over (data, model): tokens
  stay on their data shard, experts live on model shards; dispatch into a
  local [E, C_loc, D] buffer, all_to_all over the model axis to the expert
  owners, batched expert matmuls, reverse all_to_all, local combine.  This
  is the GShard/Switch EP flow; collective bytes = 2 round-trips of the
  capacity buffer per layer, FLOPs ~ capacity_factor x active.

Experts whose count does not divide the model axis (granite: 40 on 16) are
padded to the next multiple (48); phantom experts receive zero capacity
weight and ~20% FLOP overhead, recorded in the roofline notes.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig
from repro.models.layers import mlp_block

try:
    from jax import shard_map as _shard_map  # jax >= 0.7 stable API

    def shard_map(f, mesh, in_specs, out_specs):
        return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                          check_vma=False)
except ImportError:  # pragma: no cover - older jax
    from jax.experimental.shard_map import shard_map as _shard_map_old

    def shard_map(f, mesh, in_specs, out_specs):
        return _shard_map_old(f, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, check_rep=False)

P = jax.sharding.PartitionSpec


@dataclasses.dataclass(frozen=True)
class EPSpec:
    """Expert-parallel execution context (mesh + axis names)."""
    mesh: Any
    data_axes: Tuple[str, ...]
    model_axis: str = "model"
    capacity_factor: float = 1.25

    @property
    def dp(self) -> int:
        n = 1
        for a in self.data_axes:
            n *= self.mesh.shape[a]
        return n

    @property
    def tp(self) -> int:
        return self.mesh.shape[self.model_axis]


def moe_capacity(num_tokens: int, moe: MoEConfig, capacity_factor: float = 1.25,
                 num_buckets: Optional[int] = None) -> int:
    e = num_buckets or moe.num_experts
    cap = int(num_tokens * moe.top_k * capacity_factor / e)
    return max(4, -(-cap // 4) * 4)


def _route(xt, router, k):
    """Returns (topw [T,k] f32, topi [T,k] i32, gates [T,E] f32)."""
    logits = xt.astype(jnp.float32) @ router.astype(jnp.float32)
    gates = jax.nn.softmax(logits, axis=-1)
    topw, topi = jax.lax.top_k(gates, k)
    topw = topw / jnp.maximum(topw.sum(-1, keepdims=True), 1e-9)
    return topw, topi, gates


def _dispatch_local(xt, topi, topw, e_pad: int, cap: int):
    """Local capacity dispatch.  xt: [T,D]; topi/topw: [T,k].

    Returns buf [e_pad, cap, D], and (slot_e, pos, keep, slot_t) for combine.
    """
    t, d = xt.shape
    k = topi.shape[1]
    slot_e = topi.T.reshape(-1)                   # [k*T] rank-major priority
    slot_t = jnp.tile(jnp.arange(t), k)
    onehot = jax.nn.one_hot(slot_e, e_pad, dtype=jnp.int32)
    pos_all = jnp.cumsum(onehot, axis=0) - onehot
    pos = jnp.take_along_axis(pos_all, slot_e[:, None], axis=1)[:, 0]
    keep = pos < cap
    pos = jnp.where(keep, pos, cap - 1)
    upd = jnp.where(keep[:, None], xt[slot_t], 0)
    buf = jnp.zeros((e_pad, cap, d), xt.dtype).at[slot_e, pos].add(upd, mode="drop")
    return buf, (slot_e, pos, keep, slot_t)


def _combine_local(out_buf, routing, topw, t: int, d: int, dtype):
    slot_e, pos, keep, slot_t = routing
    k = topw.shape[1]
    slot_gate = topw.T.reshape(-1)
    slot_out = out_buf[slot_e, pos] * (slot_gate * keep)[:, None].astype(dtype)
    return jnp.zeros((t, d), dtype).at[slot_t].add(slot_out)


def _expert_mlps(buf, wg, wu, wd, variant):
    h_gate = jnp.einsum("ecd,edf->ecf", buf, wg)
    h_up = jnp.einsum("ecd,edf->ecf", buf, wu)
    act = (jax.nn.silu(h_gate) if variant == "swiglu"
           else jax.nn.gelu(h_gate, approximate=True))
    return jnp.einsum("ecf,efd->ecd", act * h_up, wd)


def _aux_loss(gates, topi, e):
    frac_tokens = jnp.mean(jax.nn.one_hot(topi[:, 0], e, dtype=jnp.float32), axis=0)
    frac_gates = jnp.mean(gates, axis=0)
    return e * jnp.sum(frac_tokens * frac_gates)


# --------------------------------------------------------------------------
# global-view path (un-meshed smoke tests)
# --------------------------------------------------------------------------

def moe_block_global(x, p, moe: MoEConfig, mlp_variant: str, *,
                     capacity_factor: float = 1.25,
                     constrain=lambda t, spec: t):
    b, s, d = x.shape
    t = b * s
    xt = x.reshape(t, d)
    topw, topi, gates = _route(xt, p["router"], moe.top_k)
    cap = moe_capacity(t, moe, capacity_factor)
    buf, routing = _dispatch_local(xt, topi, topw, moe.num_experts, cap)
    out_buf = _expert_mlps(buf, p["w_gate"], p["w_up"], p["w_down"], mlp_variant)
    y = _combine_local(out_buf, routing, topw, t, d, x.dtype)
    if moe.shared_expert_ff:
        y = y + mlp_block(xt, p["shared"], mlp_variant)
    return y.reshape(b, s, d), _aux_loss(gates, topi, moe.num_experts)


# --------------------------------------------------------------------------
# expert-parallel shard_map path (production mesh)
# --------------------------------------------------------------------------

def moe_block_ep(x, p, moe: MoEConfig, mlp_variant: str, ep: EPSpec, *,
                 constrain=lambda t, spec: t):
    b, s, d = x.shape
    t = b * s
    e, k = moe.num_experts, moe.top_k
    tp = ep.tp
    e_pad = -(-e // tp) * tp
    # Shard tokens over (data x model) jointly when possible: with tokens
    # only data-sharded, every model rank would dispatch the SAME tokens and
    # the all_to_all would deliver tp identical copies to each expert —
    # correct but tp-x duplicated compute (measured 16x on granite).
    token_axes = (ep.data_axes + (ep.model_axis,)
                  if t % (ep.dp * tp) == 0 else ep.data_axes)
    shards = ep.dp * tp if t % (ep.dp * tp) == 0 else ep.dp
    t_loc = t // shards
    cap = moe_capacity(t_loc, moe, ep.capacity_factor, num_buckets=e_pad)

    xt = x.reshape(t, d)
    xt = constrain(xt, "moe_tokens")      # align tokens to the EP layout
                                          # BEFORE shard_map (kills GSPMD's
                                          # "involuntary full remat" path)
    topw, topi, gates = _route(xt, p["router"], k)

    wg, wu, wd = p["w_gate"], p["w_up"], p["w_down"]
    if e_pad != e:
        padn = e_pad - e
        wg = jnp.concatenate([wg, jnp.zeros((padn,) + wg.shape[1:], wg.dtype)], 0)
        wu = jnp.concatenate([wu, jnp.zeros((padn,) + wu.shape[1:], wu.dtype)], 0)
        wd = jnp.concatenate([wd, jnp.zeros((padn,) + wd.shape[1:], wd.dtype)], 0)

    db = ep.data_axes
    ma = ep.model_axis

    def local_fn(xt_l, topw_l, topi_l, wg_l, wu_l, wd_l):
        # xt_l: [T_loc, D]; w*_l: [E_loc, D, F]
        buf, routing = _dispatch_local(xt_l, topi_l, topw_l, e_pad, cap)
        # to expert owners: [E_pad, C, D] -> [E_loc, tp*C, D]
        buf = jax.lax.all_to_all(buf, ma, split_axis=0, concat_axis=1,
                                 tiled=True)
        out = _expert_mlps(buf, wg_l, wu_l, wd_l, mlp_variant)
        # back to token owners: [E_loc, tp*C, D] -> [E_pad, C, D]
        out = jax.lax.all_to_all(out, ma, split_axis=1, concat_axis=0,
                                 tiled=True)
        return _combine_local(out, routing, topw_l, xt_l.shape[0], d, xt_l.dtype)

    y = shard_map(
        local_fn, ep.mesh,
        in_specs=(P(token_axes, None), P(token_axes, None),
                  P(token_axes, None),
                  P(ma, None, None), P(ma, None, None), P(ma, None, None)),
        out_specs=P(token_axes, None),
    )(xt, topw, topi, wg, wu, wd)

    if moe.shared_expert_ff:
        y = y + mlp_block(xt, p["shared"], mlp_variant)
    return y.reshape(b, s, d), _aux_loss(gates, topi, e)


def moe_block(x, p, moe: MoEConfig, mlp_variant: str, *,
              capacity_factor: float = 1.25,
              constrain=lambda t, spec: t, ep: Optional[EPSpec] = None):
    if ep is not None:
        return moe_block_ep(x, p, moe, mlp_variant, ep, constrain=constrain)
    return moe_block_global(x, p, moe, mlp_variant,
                            capacity_factor=capacity_factor,
                            constrain=constrain)


def init_moe_params(key, d_model: int, moe: MoEConfig, dtype):
    ks = jax.random.split(key, 5)
    scale = 0.02
    p = {
        "router": jax.random.normal(ks[0], (d_model, moe.num_experts), jnp.float32) * scale,
        "w_gate": jax.random.normal(ks[1], (moe.num_experts, d_model, moe.expert_ff), dtype) * scale,
        "w_up": jax.random.normal(ks[2], (moe.num_experts, d_model, moe.expert_ff), dtype) * scale,
        "w_down": jax.random.normal(ks[3], (moe.num_experts, moe.expert_ff, d_model), dtype) * scale,
    }
    if moe.shared_expert_ff:
        kk = jax.random.split(ks[4], 3)
        p["shared"] = {
            "w_gate": jax.random.normal(kk[0], (d_model, moe.shared_expert_ff), dtype) * scale,
            "w_up": jax.random.normal(kk[1], (d_model, moe.shared_expert_ff), dtype) * scale,
            "w_down": jax.random.normal(kk[2], (moe.shared_expert_ff, d_model), dtype) * scale,
        }
    return p
