"""Mamba2 / SSD (state-space duality) block, TPU-adapted.

The chunked SSD algorithm is reorganised for MXU-friendliness and FLOP
visibility: all intra-chunk work is batched matmuls over every chunk at once
(no scan), and the only sequential piece — the inter-chunk state recurrence —
uses ``jax.lax.associative_scan`` (visible to cost_analysis, log-depth).

Sharding note: the reference Mamba2 uses one fused ``in_proj`` whose output
is split at offsets that do not align with any tensor-parallel sharding of
the fused dim — on a 16-way model axis this forces a reshard per split per
layer (measured: 58k collectives / 490 s compile for 48 layers).  We instead
keep five separate projections (z, x, B, C, dt) and three depthwise convs
(x, B, C); each output dim (d_inner, G*N, n_heads) is individually
16-divisible, so TP stays aligned end-to-end.  Math is identical.

Shapes follow the paper: d_inner = expand*d_model, H = d_inner/headdim heads,
state N, chunk length Q.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import SSMConfig
from repro.models.layers import rms_norm


def _segsum(a):
    """Stable segment-sum: out[..., i, j] = sum a[..., j+1..i], -inf for j>i.

    a: [..., Q] -> [..., Q, Q] lower-triangular log-decay matrix.
    """
    q = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    d = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((q, q), bool), k=0)
    return jnp.where(mask, d, -jnp.inf)


def ssd_chunked(x, dt, A, B, C, *, chunk: int):
    """SSD forward over a full sequence.

    x: [b, s, h, p]; dt: [b, s, h] (post-softplus); A: [h] (negative);
    B, C: [b, s, g, n] with h % g == 0.  Returns y: [b, s, h, p] and the
    final state [b, h, p, n].
    """
    b, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    q = min(chunk, s)
    assert s % q == 0, (s, q)
    nc = s // q
    rep = h // g

    xb = x.reshape(b, nc, q, h, p)
    dtb = dt.reshape(b, nc, q, h)
    Bb = jnp.repeat(B.reshape(b, nc, q, g, n), rep, axis=3)   # [b,nc,q,h,n]
    Cb = jnp.repeat(C.reshape(b, nc, q, g, n), rep, axis=3)

    a = dtb * A[None, None, None, :]                          # [b,nc,q,h] log-decay
    a_hc = a.transpose(0, 1, 3, 2)                            # [b,nc,h,q]
    L = jnp.exp(_segsum(a_hc))                                # [b,nc,h,q,q]

    # ---- intra-chunk (batched over all chunks; no scan) ----
    cb = jnp.einsum("bcqhn,bckhn->bchqk", Cb, Bb)             # [b,nc,h,q,q]
    dtx = xb * dtb[..., None]                                 # [b,nc,q,h,p]
    y_intra = jnp.einsum("bchqk,bckhp->bcqhp", cb * L, dtx)

    # ---- chunk states ----
    cum = jnp.cumsum(a_hc, axis=-1)                           # [b,nc,h,q]
    decay_to_end = jnp.exp(cum[..., -1:] - cum)               # [b,nc,h,q]
    states = jnp.einsum("bcqhn,bchq,bcqhp->bchpn", Bb, decay_to_end, dtx)

    # ---- inter-chunk recurrence via associative scan ----
    # h_c = h_{c-1} * exp(sum_a_c) + states_c ;  pairs (decay, state)
    chunk_decay = jnp.exp(cum[..., -1])                       # [b,nc,h]

    def combine(left, right):
        dl, sl = left
        dr, sr = right
        return dl * dr, sl * dr[..., None, None] + sr

    dec_all, st_all = jax.lax.associative_scan(
        combine, (chunk_decay, states), axis=1)
    # prefix state BEFORE chunk c
    st_prev = jnp.concatenate(
        [jnp.zeros_like(st_all[:, :1]), st_all[:, :-1]], axis=1)

    # ---- inter-chunk output ----
    decay_in = jnp.exp(cum)                                   # decay from chunk start
    y_inter = jnp.einsum("bcqhn,bchq,bchpn->bcqhp", Cb, decay_in, st_prev)

    y = (y_intra + y_inter).reshape(b, s, h, p)
    final_state = st_all[:, -1]                               # [b,h,p,n]
    return y, final_state


def ssd_decode_step(state, x, dt, A, B, C):
    """Single-token SSD recurrence.

    state: [b, h, p, n]; x: [b, h, p]; dt: [b, h]; B, C: [b, g, n].
    """
    h, g = x.shape[1], B.shape[1]
    rep = h // g
    Bh = jnp.repeat(B, rep, axis=1)                           # [b,h,n]
    Ch = jnp.repeat(C, rep, axis=1)
    decay = jnp.exp(dt * A[None, :])                          # [b,h]
    state = state * decay[..., None, None] + jnp.einsum(
        "bhp,bhn->bhpn", x * dt[..., None], Bh)
    y = jnp.einsum("bhpn,bhn->bhp", state, Ch)
    return y, state


# --------------------------------------------------------------------------
# full mamba2 block: proj -> conv -> SSD -> gated norm -> out_proj
# --------------------------------------------------------------------------

def causal_conv(x, w, b):
    """Depthwise causal conv via shifts.  x: [B,S,C]; w: [K,C]; b: [C]."""
    k = w.shape[0]
    out = x * w[-1]
    for i in range(1, k):
        shifted = jnp.pad(x, ((0, 0), (i, 0), (0, 0)))[:, :-i]
        out = out + shifted * w[k - 1 - i]
    return out + b


def _conv_step(cache, x_t, w, b):
    """Single-token conv.  cache: [B,K-1,C]; x_t: [B,1,C]."""
    full = jnp.concatenate([cache, x_t], axis=1)              # [B,K,C]
    y = (full * w[None]).sum(axis=1, keepdims=True) + b
    return y, full[:, 1:]


def mamba2_block(x, p, ssm: SSMConfig, *, mode: str, cache=None,
                 constrain=lambda t, role: t):
    """x: [B,S,D] (S=1 for decode).  Returns (y, new_cache)."""
    b, s, d = x.shape
    din = ssm.expand * d
    g, n = ssm.ngroups, ssm.state_dim
    h = din // ssm.head_dim
    p_dim = ssm.head_dim

    z = x @ p["in_z"]                                         # [B,S,din]
    xs = x @ p["in_x"]                                        # [B,S,din]
    B_ = x @ p["in_B"]                                        # [B,S,g*n]
    C_ = x @ p["in_C"]                                        # [B,S,g*n]
    dt = x @ p["in_dt"]                                       # [B,S,h]
    xs = constrain(xs, "ssm_inner")
    z = constrain(z, "ssm_inner")
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])

    new_cache = {}
    if mode == "decode":
        xs, cx = _conv_step(cache["conv_x"], xs, p["conv_x_w"], p["conv_x_b"])
        B_, cB = _conv_step(cache["conv_B"], B_, p["conv_B_w"], p["conv_B_b"])
        C_, cC = _conv_step(cache["conv_C"], C_, p["conv_C_w"], p["conv_C_b"])
        new_cache.update(conv_x=cx, conv_B=cB, conv_C=cC)
    else:
        if mode == "prefill":
            k = ssm.conv_width

            def tail(t):
                pre = jnp.pad(t, ((0, 0), (k - 1, 0), (0, 0)))
                return pre[:, -(k - 1):]
            new_cache.update(conv_x=tail(xs), conv_B=tail(B_), conv_C=tail(C_))
        xs = causal_conv(xs, p["conv_x_w"], p["conv_x_b"])
        B_ = causal_conv(B_, p["conv_B_w"], p["conv_B_b"])
        C_ = causal_conv(C_, p["conv_C_w"], p["conv_C_b"])
    xs = jax.nn.silu(xs)
    B_ = jax.nn.silu(B_)
    C_ = jax.nn.silu(C_)
    xs = xs.reshape(b, s, h, p_dim)
    B_ = B_.reshape(b, s, g, n)
    C_ = C_.reshape(b, s, g, n)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))              # [h]

    if mode == "decode":
        y, st = ssd_decode_step(cache["state"], xs[:, 0].astype(jnp.float32),
                                dt[:, 0], A, B_[:, 0].astype(jnp.float32),
                                C_[:, 0].astype(jnp.float32))
        y = y[:, None]
        new_cache["state"] = st
    else:
        y, st = ssd_chunked(xs.astype(jnp.float32), dt, A,
                            B_.astype(jnp.float32), C_.astype(jnp.float32),
                            chunk=ssm.chunk_size)
        if mode == "prefill":
            new_cache["state"] = st

    y = y + xs.astype(jnp.float32) * p["D"][None, None, :, None]
    y = y.reshape(b, s, din).astype(x.dtype)
    y = constrain(y, "ssm_inner")
    y = rms_norm(y * jax.nn.silu(z), p["norm"])               # gated RMSNorm
    return y @ p["out_proj"], (new_cache if new_cache else cache)


def init_mamba2_params(key, d_model: int, ssm: SSMConfig, dtype):
    din = ssm.expand * d_model
    g, n = ssm.ngroups, ssm.state_dim
    h = din // ssm.head_dim
    k = ssm.conv_width
    ks = jax.random.split(key, 6)
    s = 0.02
    return {
        "in_z": jax.random.normal(ks[0], (d_model, din), dtype) * s,
        "in_x": jax.random.normal(ks[1], (d_model, din), dtype) * s,
        "in_B": jax.random.normal(ks[2], (d_model, g * n), dtype) * s,
        "in_C": jax.random.normal(ks[3], (d_model, g * n), dtype) * s,
        "in_dt": jax.random.normal(ks[4], (d_model, h), dtype) * s,
        "conv_x_w": jax.random.normal(ks[5], (k, din), jnp.float32) * 0.2,
        "conv_x_b": jnp.zeros((din,), jnp.float32),
        "conv_B_w": jnp.zeros((k, g * n), jnp.float32) + 0.25,
        "conv_B_b": jnp.zeros((g * n,), jnp.float32),
        "conv_C_w": jnp.zeros((k, g * n), jnp.float32) + 0.25,
        "conv_C_b": jnp.zeros((g * n,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "A_log": jnp.zeros((h,), jnp.float32),                # A = -1
        "D": jnp.ones((h,), jnp.float32),
        "norm": jnp.zeros((din,), jnp.float32),
        "out_proj": jax.random.normal(ks[0], (din, d_model), dtype) * s,
    }


def init_ssm_cache(batch: int, d_model: int, ssm: SSMConfig, dtype):
    din = ssm.expand * d_model
    g, n = ssm.ngroups, ssm.state_dim
    h = din // ssm.head_dim
    k = ssm.conv_width
    return {
        "conv_x": jnp.zeros((batch, k - 1, din), dtype),
        "conv_B": jnp.zeros((batch, k - 1, g * n), dtype),
        "conv_C": jnp.zeros((batch, k - 1, g * n), dtype),
        "state": jnp.zeros((batch, h, ssm.head_dim, n), jnp.float32),
    }
