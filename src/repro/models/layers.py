"""Core layers: norms, rotary embeddings, MLP variants, attention.

Three attention execution strategies, chosen by the caller per shape so that
every (arch x shape) cell lowers with a sane memory footprint AND with FLOPs
that are visible to ``compiled.cost_analysis()`` wherever possible:

- ``attention_full``      : materialised scores, causal/window mask.  Used for
                            train_4k (S<=4k) and for single-token decode.
- ``attention_blockwise`` : flash-style running-softmax scan over KV chunks.
                            Used for 32k global-attention prefill.  The scan
                            body is counted ONCE by cost_analysis; the known
                            trip count is corrected analytically in
                            benchmarks/roofline.py.
- ``attention_sliding_blocked`` : sliding-window attention computed on
                            (block, 2*window) tiles with no scan — exact for
                            local layers and fully FLOP-visible.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -2.3819763e38  # large negative for masking (bf16-safe)


# --------------------------------------------------------------------------
# norms / activations
# --------------------------------------------------------------------------

def rms_norm(x, scale, eps=1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(dt)


def softcap(x, cap: float):
    if not cap:
        return x
    return jnp.tanh(x / cap) * cap


def mlp_block(x, p, variant: str):
    """SwiGLU / GeGLU gated MLP."""
    gate = x @ p["w_gate"]
    up = x @ p["w_up"]
    act = jax.nn.silu(gate) if variant == "swiglu" else jax.nn.gelu(gate, approximate=True)
    return (act * up) @ p["w_down"]


# --------------------------------------------------------------------------
# rotary embeddings (RoPE and qwen2-vl M-RoPE)
# --------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: [B, S, H, hd]; positions: [B, S] int32."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                          # [hd/2]
    ang = positions.astype(jnp.float32)[..., None] * freqs  # [B, S, hd/2]
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x, positions_thw, theta: float, sections):
    """qwen2-vl multimodal RoPE.  positions_thw: [3, B, S] (t, h, w ids).

    The rotary spectrum is partitioned into ``sections`` (halved-dim units);
    each section takes its angle from the matching positional stream.
    """
    import numpy as np
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                              # [hd/2]
    ang_each = positions_thw.astype(jnp.float32)[..., None] * freqs  # [3, B, S, hd/2]
    idx = jnp.asarray(np.repeat(np.arange(3), np.asarray(sections)))  # [hd/2] static
    ang = jnp.take_along_axis(
        jnp.moveaxis(ang_each, 0, -1), idx[None, None, :, None], axis=-1
    )[..., 0]                                                  # [B, S, hd/2]
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# attention cores
# --------------------------------------------------------------------------

def _repeat_kv(k, n_rep: int):
    if n_rep == 1:
        return k
    b, s, h, d = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, h, n_rep, d)).reshape(b, s, h * n_rep, d)


def attention_full(q, k, v, *, causal: bool, window: int = 0,
                   logit_cap: float = 0.0, scale: float, q_offset=0,
                   kv_len: Optional[jnp.ndarray] = None):
    """Materialised-scores attention.

    q: [B, Sq, Hq, hd]; k, v: [B, Sk, Hkv, hd].
    ``q_offset``: absolute position of q[0] (decode: cache index).
    ``kv_len``: optional valid KV length (decode with preallocated cache).
    """
    b, sq, hq, hd = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    k = _repeat_kv(k, hq // hkv)
    v = _repeat_kv(v, hq // hkv)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    scores = softcap(scores, logit_cap)
    qpos = jnp.arange(sq)[:, None] + q_offset                  # [Sq,1]
    kpos = jnp.arange(sk)[None, :]                             # [1,Sk]
    mask = jnp.ones((sq, sk), dtype=bool)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    if kv_len is not None:
        mask = mask & (kpos < kv_len)
    scores = jnp.where(mask[None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


def attention_blockwise(q, k, v, *, causal: bool, logit_cap: float = 0.0,
                        scale: float, chunk: int = 1024):
    """Flash-style attention: scan over KV chunks with running max/denom.

    Exact (same math as flash attention); memory O(Sq * chunk).  Trip count
    = Sk // chunk (corrected for in the roofline FLOP accounting).
    """
    b, sq, hq, hd = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    assert sk % chunk == 0, (sk, chunk)
    n_chunks = sk // chunk
    k = k.reshape(b, n_chunks, chunk, hkv, hd)
    v = v.reshape(b, n_chunks, chunk, hkv, hd)
    n_rep = hq // hkv

    qpos = jnp.arange(sq)[:, None]

    def body(carry, inputs):
        m, l, acc = carry
        kc, vc, ci = inputs                                    # [b,chunk,hkv,hd], idx
        kc = _repeat_kv(kc, n_rep)
        vc = _repeat_kv(vc, n_rep)
        s = jnp.einsum("bqhd,bkhd->bhqk", q, kc).astype(jnp.float32) * scale
        s = softcap(s, logit_cap)
        if causal:
            kpos = ci * chunk + jnp.arange(chunk)[None, :]
            s = jnp.where((kpos <= qpos)[None, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhqk,bkhd->bhqd", p.astype(q.dtype), vc).astype(jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, hq, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hq, sq), jnp.float32)
    a0 = jnp.zeros((b, hq, sq, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0),
        (k.swapaxes(0, 1), v.swapaxes(0, 1), jnp.arange(n_chunks)))
    out = acc / jnp.maximum(l, 1e-37)[..., None]
    return out.swapaxes(1, 2).astype(q.dtype)                  # [B,Sq,H,hd]


def attention_sliding_blocked(q, k, v, *, window: int, logit_cap: float = 0.0,
                              scale: float):
    """Causal sliding-window attention on (block, 2*window) tiles, no scan.

    Each block of ``window`` queries attends to [its block, previous block];
    with causal+window masking inside the tile this is exact sliding-window
    attention.  FLOPs ~ 2 * S * window per head-dim unit, all visible to
    cost_analysis.
    """
    b, s, hq, hd = q.shape
    hkv = k.shape[2]
    k = _repeat_kv(k, hq // hkv)
    v = _repeat_kv(v, hq // hkv)
    w = window
    assert s % w == 0, (s, w)
    nb = s // w
    qb = q.reshape(b, nb, w, hq, hd)
    kb = k.reshape(b, nb, w, hq, hd)
    vb = v.reshape(b, nb, w, hq, hd)
    # previous block (zeros before block 0)
    kprev = jnp.concatenate([jnp.zeros_like(kb[:, :1]), kb[:, :-1]], axis=1)
    vprev = jnp.concatenate([jnp.zeros_like(vb[:, :1]), vb[:, :-1]], axis=1)
    k2 = jnp.concatenate([kprev, kb], axis=2)                  # [b,nb,2w,h,d]
    v2 = jnp.concatenate([vprev, vb], axis=2)
    scores = jnp.einsum("bnqhd,bnkhd->bnhqk", qb, k2).astype(jnp.float32) * scale
    scores = softcap(scores, logit_cap)
    qpos = jnp.arange(w)[:, None] + w                          # within 2w frame
    kpos = jnp.arange(2 * w)[None, :]
    mask = (kpos <= qpos) & (kpos > qpos - w)
    first = (jnp.arange(nb) == 0)[None, :, None, None, None]
    valid = jnp.where(first & (kpos < w)[None, None, None], False, mask[None, None, None])
    scores = jnp.where(valid, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bnhqk,bnkhd->bnqhd", probs, v2)
    return out.reshape(b, s, hq, hd)
