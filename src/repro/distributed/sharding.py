"""Sharding plan: logical roles -> PartitionSpecs, with divisibility fallbacks.

Baseline parallelism (DESIGN.md §5):
- batch           -> ("pod","data")          data parallelism (+ flight axis)
- weight dim0/in  -> "data"                  ZeRO-3/FSDP parameter sharding
- weight out/TP   -> "model"                 tensor parallelism (heads/ff/vocab)
- experts         -> "model"                 expert parallelism
- activations     -> constrained at key points via ``plan.constrain``

Every rule checks divisibility and degrades to replication rather than
erroring, so all ten architectures (incl. 40-expert / 12-head / odd-vocab
configs) lower on the fixed 16x16 and 2x16x16 meshes.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.launch.mesh import batch_axes


def _axes_size(mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        return mesh.shape[axes]
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


@dataclasses.dataclass
class Plan:
    mesh: Any
    cfg: ModelConfig
    # axis-name knobs (overridable for perf experiments)
    data: Any = None          # filled in __post_init__
    model: str = "model"
    zero3: bool = True        # shard params+opt state over data axis
    # §Perf variants (benchmarks/hillclimb.py):
    seq_parallel: Optional[bool] = None  # residual sharded over model on seq;
    # None = auto: ON for archs whose head count doesn't divide the model
    # axis (measured 2-2.5x on the collective term, EXPERIMENTS.md §Perf)
    moe_token_align: bool = False  # pre-shard tokens to the EP layout

    def __post_init__(self):
        self.data = batch_axes(self.mesh)
        if self.seq_parallel is None:
            tp = _axes_size(self.mesh, self.model)
            self.seq_parallel = bool(self.cfg.num_heads
                                     and self.cfg.num_heads % tp != 0)

    # -- helpers ------------------------------------------------------------
    def _ok(self, dim: int, axes) -> bool:
        n = _axes_size(self.mesh, axes)
        return n > 1 and dim % n == 0

    def _pick(self, shape, rules):
        """rules: list of (dim_index, axes) applied if divisible & unused."""
        spec = [None] * len(shape)
        used = set()
        for d, axes in rules:
            if axes is None:
                continue
            key = tuple(axes) if not isinstance(axes, str) else (axes,)
            if any(a in used for a in key):
                continue
            if self._ok(shape[d], axes) and spec[d] is None:
                spec[d] = axes
                used.update(key)
        return P(*spec)

    # -- parameters ---------------------------------------------------------
    def param_spec(self, path: str, shape) -> P:
        """PartitionSpec for a parameter, keyed by its pytree path string."""
        name = path.split("/")[-1]
        fsdp = self.data if self.zero3 else None
        if name == "embed":
            return self._pick(shape, [(0, self.model), (1, fsdp)])
        if name == "lm_head":
            return self._pick(shape, [(1, self.model), (0, fsdp)])
        if name == "router":
            return self._pick(shape, [(0, fsdp)])
        if name in ("w_gate", "w_up") and len(shape) == 3:   # MoE experts [E,D,F]
            return self._pick(shape, [(0, self.model), (1, fsdp), (2, self.model)])
        if name == "w_down" and len(shape) == 3:             # [E,F,D]
            return self._pick(shape, [(0, self.model), (1, self.model), (2, fsdp)])
        if name in ("wq", "wk", "wv", "w_gate", "w_up",
                    "in_z", "in_x", "in_B", "in_C", "in_dt"):
            return self._pick(shape, [(0, fsdp), (1, self.model)])
        if name in ("wo", "w_down", "out_proj"):
            return self._pick(shape, [(0, self.model), (1, fsdp)])
        if name in ("conv_x_w", "conv_B_w", "conv_C_w"):
            return self._pick(shape, [(1, self.model)])
        return P()  # norms, biases, A_log, dt_bias, D: replicated

    def param_shardings(self, params_shape):
        """Map a params pytree (of ShapeDtypeStruct or arrays) to shardings."""
        def one(path, leaf):
            pstr = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                            for k in path)
            return NamedSharding(self.mesh, self.param_spec(pstr, leaf.shape))
        return jax.tree_util.tree_map_with_path(one, params_shape)

    # -- activations --------------------------------------------------------
    def act_spec(self, role: str, shape) -> Optional[P]:
        cfg = self.cfg
        b = self.data
        if role == "act_resid":                              # [B,S,D]
            if self.seq_parallel and self._ok(shape[1], self.model):
                return P(b, self.model, None)
            return P(b, None, None)
        if role == "moe_tokens":                             # [T,D] pre-EP
            if not self.moe_token_align:
                return None                                  # baseline
            axes = (*b, self.model)
            if self._ok(shape[0], axes):
                return P(axes, None)
            return P(b, None)
        if role == "act_heads":                              # [B,S,H,hd]
            rules = [(0, b)]
            rules.append((2, self.model) if self._ok(shape[2], self.model)
                         else (1, self.model))
            return self._pick(shape, rules)
        if role == "act_kv_heads":
            rules = [(0, b)]
            if self._ok(shape[2], self.model):
                rules.append((2, self.model))
            return self._pick(shape, rules)
        if role == "act_ff_out":
            return P(b, None, None)
        if role == "logits":                                 # [B,S,V]
            if self._ok(shape[-1], self.model):
                return P(b, None, self.model)
            return self._pick(shape, [(0, b), (1, self.model)])
        if role == "moe_logits":                             # [T,E]
            return P(b, None)
        if role == "moe_buffer":                             # [E,C,D]
            rules = []
            if self._ok(shape[0], self.model):
                rules.append((0, self.model))
            rules.append((1, b))
            return self._pick(shape, rules)
        if role == "moe_w_in":                               # [E,D,F] compute
            if self._ok(shape[0], self.model):
                return P(self.model, None, None)
            return self._pick(shape, [(2, self.model)])
        if role == "moe_w_out":                              # [E,F,D] compute
            if self._ok(shape[0], self.model):
                return P(self.model, None, None)
            return self._pick(shape, [(1, self.model)])
        if role == "ssm_inner":                              # [B,S,din]
            return self._pick(shape, [(0, b), (2, self.model)])
        if role == "kv_cache":                               # [B,C,hkv,hd]
            rules = [(0, b)] if shape[0] > 1 else [(1, b)]   # seq-shard for B=1
            if self._ok(shape[2], self.model):
                rules.append((2, self.model))
            else:
                # kv heads don't divide the model axis: shard the SEQ dim.
                # (head_dim sharding makes GSPMD all-gather the whole cache
                # — measured 43 GB/step on gemma2 decode_32k; seq sharding
                # keeps the contraction local and the softmax reduction is
                # scalar-sized.)
                rules.append((1, self.model))
            return self._pick(shape, rules)
        if role == "ssm_state":                              # [B,H,P,N]
            rules = [(0, b)] if shape[0] > 1 else []
            if self._ok(shape[1], self.model):
                rules.append((1, self.model))
            return self._pick(shape, rules)
        if role == "conv_cache":                             # [B,K-1,C]
            rules = [(0, b)] if shape[0] > 1 else []
            if self._ok(shape[2], self.model):
                rules.append((2, self.model))
            return self._pick(shape, rules)
        return None

    def constrain(self, t, role: str):
        spec = self.act_spec(role, t.shape)
        if spec is None:
            return t
        try:
            return jax.lax.with_sharding_constraint(
                t, NamedSharding(self.mesh, spec))
        except ValueError:
            return t

    # -- batches / caches ---------------------------------------------------
    def batch_shardings(self, batch_shape):
        b = self.data

        def one(path, leaf):
            name = str(getattr(path[-1], "key", "")) if path else ""
            if name == "positions" and len(leaf.shape) == 3:  # mrope [3,B,S]
                return NamedSharding(self.mesh, P(None, b, None))
            spec = [None] * len(leaf.shape)
            if leaf.shape and leaf.shape[0] > 1 and self._ok(leaf.shape[0], b):
                spec[0] = b
            return NamedSharding(self.mesh, P(*spec))
        return jax.tree_util.tree_map_with_path(one, batch_shape)

    def cache_shardings(self, cache_shape):
        def one(path, leaf):
            names = [str(getattr(k, "key", "")) for k in path]
            nm = names[-1]
            if nm in ("k", "v", "cross_k", "cross_v"):
                role = "kv_cache"
            elif nm == "state":
                role = "ssm_state"
            elif nm.startswith("conv"):
                role = "conv_cache"
            else:
                return NamedSharding(self.mesh, P())
            spec = self.act_spec(role, leaf.shape)
            return NamedSharding(self.mesh, spec if spec else P())
        return jax.tree_util.tree_map_with_path(one, cache_shape)
