"""Distributed-optimization helpers: gradient compression and
straggler-tolerant aggregation transforms.

``compress_grads`` returns a grad_transform for training.step.make_train_step:
- "bf16": cast gradients to bf16 before the (XLA-inserted) all-reduce —
  halves DP collective bytes; update math stays f32.
- "int8": per-tensor symmetric int8 quantisation with stochastic rounding —
  4x fewer bytes; error feedback keeps the bias bounded (residual carried
  in the caller's state when used via EFState).
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp


def _stochastic_round_int8(x, key, scale):
    y = x / scale * 127.0
    noise = jax.random.uniform(key, y.shape, minval=-0.5, maxval=0.5)
    return jnp.clip(jnp.round(y + noise), -127, 127).astype(jnp.int8)


def compress_grads(mode: Optional[str], seed: int = 0) -> Optional[Callable]:
    if mode is None:
        return None
    if mode == "bf16":
        def t(grads):
            return jax.tree.map(
                lambda g: g.astype(jnp.bfloat16).astype(g.dtype), grads)
        return t
    if mode == "int8":
        def t(grads):
            leaves, treedef = jax.tree_util.tree_flatten(grads)
            out = []
            for i, g in enumerate(leaves):
                key = jax.random.fold_in(jax.random.PRNGKey(seed), i)
                scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-8)
                q = _stochastic_round_int8(g.astype(jnp.float32), key, scale)
                out.append((q.astype(jnp.float32) * scale / 127.0
                            ).astype(g.dtype))
            return jax.tree_util.tree_unflatten(treedef, out)
        return t
    raise ValueError(f"unknown compression mode {mode!r}")


def drop_straggler_transform(weights) -> Callable:
    """Scale per-shard gradient contributions (already summed by GSPMD) by
    renormalised weights — used with per-sample loss weighting in
    training.raptor_dp; provided here for explicit-collective setups."""
    def t(grads):
        w = jnp.asarray(weights, jnp.float32)
        norm = w.sum() / w.size
        return jax.tree.map(lambda g: g / jnp.maximum(norm, 1e-6), grads)
    return t
