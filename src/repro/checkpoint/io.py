"""Sharded checkpointing with async save and crash-safe commit.

Layout: <dir>/step_<N>/shard_<host>.npz + manifest.json written LAST (the
commit point — a restore only considers directories with a manifest, so a
mid-write crash leaves no corrupt restore target).  Orbax-free on purpose:
the container has no network; the format is plain npz + json and maps 1:1
onto a per-host GCS/posixfs layout at fleet scale.
"""
from __future__ import annotations

import dataclasses
import json
import os
import shutil
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> List[Tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out.append((key, leaf))
    return out


def save(ckpt_dir: str, step: int, state, *, host_id: int = 0,
         keep: int = 3, block: bool = True) -> threading.Thread:
    """Write one host's shard of ``state``; manifest commits the step."""
    def _write():
        d = os.path.join(ckpt_dir, f"step_{step:08d}")
        os.makedirs(d, exist_ok=True)
        arrays = {k: np.asarray(v) for k, v in _flatten(state)}
        np.savez(os.path.join(d, f"shard_{host_id}.npz"), **arrays)
        manifest = {
            "step": step,
            "host_id": host_id,
            "keys": sorted(arrays),
            "format": 1,
        }
        with open(os.path.join(d, f"manifest_{host_id}.json"), "w") as f:
            json.dump(manifest, f)
        _gc(ckpt_dir, keep)

    t = threading.Thread(target=_write, daemon=True)
    t.start()
    if block:
        t.join()
    return t


def _gc(ckpt_dir: str, keep: int):
    steps = sorted(latest_steps(ckpt_dir))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"),
                      ignore_errors=True)


def latest_steps(ckpt_dir: str) -> List[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_"):
            d = os.path.join(ckpt_dir, name)
            if any(f.startswith("manifest_") for f in os.listdir(d)):
                out.append(int(name.split("_")[1]))
    return sorted(out)


def restore(ckpt_dir: str, state_like, *, step: Optional[int] = None,
            host_id: int = 0):
    """Restore into the structure of ``state_like``.  Returns (state, step).
    Raises FileNotFoundError when no committed checkpoint exists."""
    steps = latest_steps(ckpt_dir)
    if not steps:
        raise FileNotFoundError(f"no committed checkpoints under {ckpt_dir}")
    step = steps[-1] if step is None else step
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with np.load(os.path.join(d, f"shard_{host_id}.npz")) as z:
        arrays = {k: z[k] for k in z.files}
    flat = _flatten(state_like)
    leaves = []
    for key, like in flat:
        if key not in arrays:
            raise KeyError(f"checkpoint missing {key}")
        a = arrays[key]
        leaves.append(jax.numpy.asarray(a, dtype=like.dtype))
    treedef = jax.tree_util.tree_structure(state_like)
    return jax.tree_util.tree_unflatten(treedef, leaves), step
