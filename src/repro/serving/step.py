"""Serving step builders: prefill and decode as pure jit-able functions."""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import transformer as tfm


def make_prefill_step(cfg: ModelConfig, max_len: int, constrain=tfm._ID,
                      ep=None):
    def prefill_step(params, batch):
        return tfm.prefill(params, cfg, batch, max_len, constrain=constrain,
                           ep=ep)
    return prefill_step


def make_decode_step(cfg: ModelConfig, constrain=tfm._ID, ep=None):
    def decode_step(params, caches, tokens):
        return tfm.decode_step(params, cfg, caches, tokens,
                               constrain=constrain, ep=ep)
    return decode_step


def cache_shape(cfg: ModelConfig, batch: int, max_len: int, enc_len: int = 0):
    """ShapeDtypeStruct pytree of the decode cache (no allocation)."""
    fn = lambda: tfm.init_cache(cfg, batch, max_len, enc_len)
    shapes = jax.eval_shape(fn)
    return shapes


def greedy_sample(logits):
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)
