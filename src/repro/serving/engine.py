"""Batched serving engine with Raptor flights over real jitted model stages.

Requests are grouped into batches; each invocation (prefill -> N decode
steps) is an ActionManifest executed by the Raptor engine.  With
``flight_size > 1`` the whole invocation is speculatively replicated across
executor groups (threads here; one process per model replica on a fleet),
with per-group latency jitter standing in for independent host/queue
variance — first finisher wins, peers are preempted (core.scheduler).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.manifest import ActionManifest, FunctionSpec
from repro.core.scheduler import Flight
from repro.models import transformer as tfm
from repro.serving.step import greedy_sample, make_decode_step, make_prefill_step


@dataclasses.dataclass
class ServeConfig:
    max_len: int = 128
    decode_steps: int = 16
    flight_size: int = 1
    # per-group latency jitter model (independent "hosts"): exp(mean_jitter)
    mean_jitter_s: float = 0.0
    seed: int = 0


@dataclasses.dataclass
class ServeResult:
    tokens: np.ndarray              # [B, decode_steps]
    latency_s: float
    flight_report: Optional[Any] = None


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, sc: ServeConfig):
        self.cfg = cfg
        self.params = params
        self.sc = sc
        self._prefill = jax.jit(make_prefill_step(cfg, sc.max_len))
        self._decode = jax.jit(make_decode_step(cfg))
        self._rng = np.random.default_rng(sc.seed)

    # ---- plain (stock) path ------------------------------------------
    def generate(self, batch: Dict[str, Any]) -> ServeResult:
        t0 = time.monotonic()
        logits, cache = self._prefill(self.params, batch)
        toks = []
        tok = greedy_sample(logits)[:, None]
        for _ in range(self.sc.decode_steps):
            toks.append(np.asarray(tok)[:, 0])
            logits, cache = self._decode(self.params, cache, tok)
            tok = greedy_sample(logits)[:, None]
        out = np.stack(toks, axis=1)
        return ServeResult(out, time.monotonic() - t0)

    # ---- Raptor flight path ------------------------------------------
    def generate_flight(self, batch: Dict[str, Any]) -> ServeResult:
        """Speculatively replicate the invocation across flight members."""
        sc = self.sc
        jitters = self._rng.exponential(
            max(sc.mean_jitter_s, 1e-9), size=(sc.flight_size, 2))

        def make_stage(stage: str):
            def fn(ctx):
                member = ctx.follower_index
                # independent host variance (queue/NIC/entropy analogue)
                if sc.mean_jitter_s:
                    ctx.sleep(float(jitters[member % sc.flight_size,
                                            0 if stage == "prefill" else 1]))
                if stage == "prefill":
                    logits, cache = self._prefill(self.params, batch)
                    return {"logits": np.asarray(logits), "cache": cache}
                pre = ctx.inputs["prefill"]
                cache = pre["cache"]
                tok = greedy_sample(jnp.asarray(pre["logits"]))[:, None]
                toks = []
                for _ in range(sc.decode_steps):
                    ctx.checkpoint()      # preemption point per decode step
                    toks.append(np.asarray(tok)[:, 0])
                    logits, cache = self._decode(self.params, cache, tok)
                    tok = greedy_sample(logits)[:, None]
                return np.stack(toks, axis=1)
            return fn

        manifest = ActionManifest((
            FunctionSpec("prefill", make_stage("prefill")),
            FunctionSpec("decode", make_stage("decode"),
                         dependencies=("prefill",)),
        ), concurrency=sc.flight_size, name="generate")
        t0 = time.monotonic()
        report = Flight(manifest).run(timeout=600.0)
        if not report.ok:
            raise RuntimeError("flight failed")
        return ServeResult(report.outputs["decode"],
                           time.monotonic() - t0, report)


def demo_requests(cfg: ModelConfig, batch: int, prompt_len: int, seed=0):
    rng = np.random.default_rng(seed)
    b: Dict[str, Any] = {}
    if cfg.embedding_inputs:
        b["embeddings"] = jnp.asarray(
            rng.standard_normal((batch, prompt_len, cfg.d_model)),
            jnp.dtype(cfg.dtype)) * 0.02
    else:
        b["tokens"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (batch, prompt_len)), jnp.int32)
    if cfg.is_encoder_decoder:
        b["enc_emb"] = jnp.asarray(
            rng.standard_normal((batch, prompt_len, cfg.d_model)),
            jnp.dtype(cfg.dtype)) * 0.02
    if cfg.mrope:
        pos = jnp.broadcast_to(jnp.arange(prompt_len)[None],
                               (batch, prompt_len))
        b["positions"] = jnp.broadcast_to(pos[None], (3, batch, prompt_len)
                                          ).astype(jnp.int32)
    return b
