"""Batched serving engine with Raptor flights over real jitted model stages.

Requests are grouped into batches; each invocation (prefill -> N decode
steps) is an ActionManifest executed by the Raptor engine.  With
``flight_size > 1`` the whole invocation is speculatively replicated across
executor groups (threads here; one process per model replica on a fleet),
with per-group latency jitter standing in for independent host/queue
variance — first finisher wins, peers are preempted (core.scheduler).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.manifest import ActionManifest, FunctionSpec
from repro.core.scheduler import Flight
from repro.models import transformer as tfm
from repro.serving.step import greedy_sample, make_decode_step, make_prefill_step


@dataclasses.dataclass
class ServeConfig:
    max_len: int = 128
    decode_steps: int = 16
    flight_size: int = 1
    # per-group latency jitter model (independent "hosts"): exp(mean_jitter)
    mean_jitter_s: float = 0.0
    seed: int = 0

    def __post_init__(self):
        if self.max_len < 1:
            raise ValueError(f"max_len must be >= 1, got {self.max_len}")
        if self.decode_steps < 1:
            raise ValueError(
                f"decode_steps must be >= 1, got {self.decode_steps}")
        if self.decode_steps >= self.max_len:
            raise ValueError(
                f"decode_steps={self.decode_steps} leaves no room for a "
                f"prompt inside max_len={self.max_len}")
        if self.flight_size < 1:
            raise ValueError(
                f"flight_size must be >= 1, got {self.flight_size}")
        if not self.mean_jitter_s >= 0.0:
            raise ValueError(
                f"mean_jitter_s must be >= 0, got {self.mean_jitter_s}")


@dataclasses.dataclass
class ServeResult:
    tokens: np.ndarray              # [B, decode_steps]
    latency_s: float                # warm wall time of THIS call (no jit)
    flight_report: Optional[Any] = None
    cold_s: Optional[float] = None  # first-compile time, when this call
    #                                 triggered the warmup (else None)
    latencies_s: Optional[np.ndarray] = None   # per-request [B] latencies


@dataclasses.dataclass
class ServeStats:
    """Per-request latency accounting over a sequence of serve calls."""
    latencies_s: np.ndarray         # one entry per request (flattened)
    cold_s: float                   # first-call compile-inclusive time
    warm_s: float                   # post-warmup single-call reference

    @property
    def p50_s(self) -> float:
        return float(np.percentile(self.latencies_s, 50))

    @property
    def p99_s(self) -> float:
        return float(np.percentile(self.latencies_s, 99))

    def summary(self) -> dict:
        return {"requests": int(self.latencies_s.size),
                "mean_s": float(self.latencies_s.mean()),
                "p50_s": self.p50_s, "p99_s": self.p99_s,
                "cold_s": self.cold_s, "warm_s": self.warm_s}


def _prompt_len(batch: Dict[str, Any]) -> int:
    for name in ("tokens", "embeddings"):
        if name in batch:
            return int(batch[name].shape[1])
    raise ValueError("batch carries neither 'tokens' nor 'embeddings'")


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, sc: ServeConfig):
        self.cfg = cfg
        self.params = params
        self.sc = sc
        self._prefill = jax.jit(make_prefill_step(cfg, sc.max_len))
        self._decode = jax.jit(make_decode_step(cfg))
        self._rng = np.random.default_rng(sc.seed)
        self._warmed = set()        # batch signatures already compiled
        self.cold_s: Optional[float] = None   # first-compile wall time
        self.warm_s: Optional[float] = None   # warm reference (same shapes)

    def _check_budget(self, batch: Dict[str, Any]) -> None:
        p = _prompt_len(batch)
        if p + self.sc.decode_steps > self.sc.max_len:
            raise ValueError(
                f"prompt_len={p} + decode_steps={self.sc.decode_steps} "
                f"overflows the max_len={self.sc.max_len} cache budget")

    def _signature(self, batch: Dict[str, Any]):
        return tuple(sorted((k, tuple(v.shape)) for k, v in batch.items()))

    def warmup(self, batch: Dict[str, Any]) -> Dict[str, float]:
        """Compile prefill+decode for this batch shape; report cold/warm.

        Explicit so a service can pay jit before taking traffic; both
        ``generate`` paths call it lazily, so measured ``latency_s`` NEVER
        includes first-call compilation (the bug this replaces timed
        ``t0`` before the first jitted call).  Deterministic and
        rng-free — warmup cannot shift the jitter draw stream.
        """
        self._check_budget(batch)
        sig = self._signature(batch)
        if sig in self._warmed:
            return {"cold_s": 0.0, "warm_s": self.warm_s or 0.0}

        def once():
            logits, cache = self._prefill(self.params, batch)
            tok = greedy_sample(logits)[:, None]
            logits, _ = self._decode(self.params, cache, tok)
            jax.block_until_ready(logits)

        t0 = time.monotonic()
        once()
        cold = time.monotonic() - t0
        t0 = time.monotonic()
        once()
        warm = time.monotonic() - t0
        self._warmed.add(sig)
        if self.cold_s is None:
            self.cold_s, self.warm_s = cold, warm
        return {"cold_s": cold, "warm_s": warm}

    # ---- plain (stock) path ------------------------------------------
    def generate(self, batch: Dict[str, Any]) -> ServeResult:
        self._check_budget(batch)
        cold = None
        if self._signature(batch) not in self._warmed:
            cold = self.warmup(batch)["cold_s"]
        t0 = time.monotonic()
        logits, cache = self._prefill(self.params, batch)
        toks = []
        tok = greedy_sample(logits)[:, None]
        for _ in range(self.sc.decode_steps):
            toks.append(np.asarray(tok)[:, 0])
            logits, cache = self._decode(self.params, cache, tok)
            tok = greedy_sample(logits)[:, None]
        out = np.stack(toks, axis=1)
        dt = time.monotonic() - t0
        return ServeResult(out, dt, cold_s=cold,
                           latencies_s=np.full(out.shape[0], dt))

    # ---- Raptor flight path ------------------------------------------
    def generate_flight(self, batch: Dict[str, Any]) -> ServeResult:
        """Speculatively replicate the invocation across flight members."""
        self._check_budget(batch)
        cold = None
        if self._signature(batch) not in self._warmed:
            cold = self.warmup(batch)["cold_s"]
        sc = self.sc
        jitters = self._rng.exponential(
            max(sc.mean_jitter_s, 1e-9), size=(sc.flight_size, 2))

        def make_stage(stage: str):
            def fn(ctx):
                member = ctx.follower_index
                # independent host variance (queue/NIC/entropy analogue)
                if sc.mean_jitter_s:
                    ctx.sleep(float(jitters[member % sc.flight_size,
                                            0 if stage == "prefill" else 1]))
                if stage == "prefill":
                    logits, cache = self._prefill(self.params, batch)
                    return {"logits": np.asarray(logits), "cache": cache}
                pre = ctx.inputs["prefill"]
                cache = pre["cache"]
                tok = greedy_sample(jnp.asarray(pre["logits"]))[:, None]
                toks = []
                for _ in range(sc.decode_steps):
                    ctx.checkpoint()      # preemption point per decode step
                    toks.append(np.asarray(tok)[:, 0])
                    logits, cache = self._decode(self.params, cache, tok)
                    tok = greedy_sample(logits)[:, None]
                return np.stack(toks, axis=1)
            return fn

        manifest = ActionManifest((
            FunctionSpec("prefill", make_stage("prefill")),
            FunctionSpec("decode", make_stage("decode"),
                         dependencies=("prefill",)),
        ), concurrency=sc.flight_size, name="generate")
        t0 = time.monotonic()
        report = Flight(manifest).run(timeout=600.0)
        if not report.ok:
            raise RuntimeError("flight failed")
        dt = time.monotonic() - t0
        out = report.outputs["decode"]
        return ServeResult(out, dt, report, cold_s=cold,
                           latencies_s=np.full(out.shape[0], dt))

    def serve(self, batches, *, raptor: bool = None) -> ServeStats:
        """Serve a sequence of request batches; per-request latency stats.

        Warmup is paid once up front (first batch's shapes), so the
        returned latency distribution is pure serve time — cold/warm
        compile ride along separately in the stats.
        """
        batches = list(batches)
        if not batches:
            raise ValueError("serve needs at least one batch")
        if raptor is None:
            raptor = self.sc.flight_size > 1
        wu = self.warmup(batches[0])
        lat = []
        for b in batches:
            res = (self.generate_flight(b) if raptor else self.generate(b))
            lat.append(res.latencies_s)
        return ServeStats(np.concatenate(lat),
                          cold_s=(self.cold_s
                                  if self.cold_s is not None
                                  else wu["cold_s"]),
                          warm_s=self.warm_s or wu["warm_s"])


class SchedulerService:
    """Live Raptor *scheduling* service: open job arrivals booked on the
    streaming sim engine's persistent device-resident W-state.

    This is the service face of :class:`repro.sim.streaming.
    StreamingScheduler` — the launcher (``repro.launch.serve --mode
    scheduler``) and the ``queue_streaming`` bench tier drive sustained
    open load through it.  ``submit``/``drain`` mirror the engine;
    ``run_open_load`` is the batteries-included sustained driver.
    """

    def __init__(self, sim, *, microbatch: int = 64,
                 pipeline_depth: int = 2, seed: Optional[int] = None):
        from repro.sim.streaming import StreamingScheduler
        self.sim = sim
        self.engine = StreamingScheduler(
            sim, microbatch=microbatch, pipeline_depth=pipeline_depth,
            seed=seed)

    def submit(self, arrivals_ms) -> None:
        self.engine.submit(arrivals_ms)

    def drain(self):
        return self.engine.drain()

    def run_open_load(self, **kw):
        from repro.sim.streaming import run_open_load
        return run_open_load(self.sim, **kw)


def demo_requests(cfg: ModelConfig, batch: int, prompt_len: int, seed=0):
    rng = np.random.default_rng(seed)
    b: Dict[str, Any] = {}
    if cfg.embedding_inputs:
        b["embeddings"] = jnp.asarray(
            rng.standard_normal((batch, prompt_len, cfg.d_model)),
            jnp.dtype(cfg.dtype)) * 0.02
    else:
        b["tokens"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (batch, prompt_len)), jnp.int32)
    if cfg.is_encoder_decoder:
        b["enc_emb"] = jnp.asarray(
            rng.standard_normal((batch, prompt_len, cfg.d_model)),
            jnp.dtype(cfg.dtype)) * 0.02
    if cfg.mrope:
        pos = jnp.broadcast_to(jnp.arange(prompt_len)[None],
                               (batch, prompt_len))
        b["positions"] = jnp.broadcast_to(pos[None], (3, batch, prompt_len)
                                          ).astype(jnp.int32)
    return b
