"""qwen2-vl-2b — VLM backbone. 28L d_model=1536 12H (GQA kv=2) d_ff=8960
vocab=151936, M-RoPE, dynamic resolution. [arXiv:2409.12191]

The vision frontend is a STUB: ``input_specs`` provides precomputed patch
embeddings plus M-RoPE (t,h,w) position ids; only the LM backbone is built.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b",
    family="vlm",
    num_layers=28,
    d_model=1536,
    num_heads=12,
    num_kv_heads=2,
    d_ff=8960,
    vocab_size=151936,
    head_dim=128,
    mlp_variant="swiglu",
    rope_theta=1000000.0,
    mrope=True,
    mrope_sections=(16, 24, 24),
    attn_pattern="global",
    tie_embeddings=True,
    embedding_inputs=True,
)
