"""Architecture registry: ``get_config("gemma2-9b")`` and friends."""
from __future__ import annotations

import dataclasses

from repro.configs.base import (
    ALL_SHAPES,
    DECODE_32K,
    LONG_500K,
    PREFILL_32K,
    TRAIN_4K,
    ModelConfig,
    MoEConfig,
    ShapeConfig,
    SSMConfig,
    applicable_shapes,
    long_context_capable,
    shape_by_name,
)

from repro.configs import (  # noqa: E402
    gemma2_9b,
    gemma3_27b,
    gemma_2b,
    granite_moe_3b,
    llama4_maverick,
    mamba2_1_3b,
    phi3_mini_3_8b,
    qwen2_vl_2b,
    seamless_m4t_medium,
    zamba2_1_2b,
)

_REGISTRY = {
    m.CONFIG.name: m.CONFIG
    for m in (
        qwen2_vl_2b, phi3_mini_3_8b, gemma2_9b, gemma_2b, gemma3_27b,
        granite_moe_3b, llama4_maverick, mamba2_1_3b, zamba2_1_2b,
        seamless_m4t_medium,
    )
}

ARCH_NAMES = tuple(sorted(_REGISTRY))


def get_config(name: str) -> ModelConfig:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown arch {name!r}; available: {ARCH_NAMES}") from None


def reduced_config(cfg: ModelConfig, *, layers: int = 2, d_model: int = 64,
                   vocab: int = 128, ff: int = 128) -> ModelConfig:
    """A tiny same-family config for CPU smoke tests.

    Keeps the structural features (GQA ratio, local/global pattern, MoE,
    SSM, hybrid sharing, enc-dec) while shrinking every dimension.
    """
    head_dim = 16
    n_heads = max(1, min(cfg.num_heads, 4)) if cfg.num_heads else 0
    n_kv = 0
    if cfg.num_heads:
        ratio = max(1, cfg.num_heads // max(cfg.num_kv_heads, 1))
        n_kv = max(1, n_heads // ratio)
    moe = None
    if cfg.moe is not None:
        moe = MoEConfig(num_experts=min(cfg.moe.num_experts, 8),
                        top_k=min(cfg.moe.top_k, 2),
                        expert_ff=32,
                        shared_expert_ff=32 if cfg.moe.shared_expert_ff else 0,
                        every_n_layers=cfg.moe.every_n_layers)
    ssm = None
    if cfg.ssm is not None:
        ssm = SSMConfig(state_dim=16, head_dim=16, expand=2, conv_width=4,
                        chunk_size=16, ngroups=1)
    n_layers = layers
    if cfg.family == "hybrid":
        n_layers = max(layers, cfg.hybrid_attn_every)  # exercise the shared block
    if cfg.attn_pattern == "local_global_5_1":
        n_layers = 6
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-smoke",
        num_layers=n_layers,
        num_encoder_layers=min(cfg.num_encoder_layers, 2),
        d_model=d_model,
        num_heads=n_heads,
        num_kv_heads=n_kv,
        head_dim=head_dim,
        d_ff=ff if cfg.d_ff else 0,
        vocab_size=vocab,
        window_size=8,
        mrope_sections=(2, 3, 3) if cfg.mrope else cfg.mrope_sections,
        moe=moe,
        ssm=ssm,
        hybrid_attn_every=min(cfg.hybrid_attn_every, 3) if cfg.hybrid_attn_every else 0,
        dtype="float32",
    )
