"""zamba2-1.2b — hybrid. 38L d_model=2048, Mamba2 backbone (d_state=64) with a
single SHARED attention+MLP block (32H, d_ff=8192) applied every 6 mamba
layers. vocab=32000. [arXiv:2411.15242]
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    num_layers=38,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8192,
    vocab_size=32000,
    head_dim=64,
    mlp_variant="geglu",
    attn_pattern="global",
    tie_embeddings=True,
    ssm=SSMConfig(state_dim=64, head_dim=64, expand=2, conv_width=4,
                  chunk_size=256, ngroups=1),
    hybrid_attn_every=6,
)
