"""llama4-maverick-400b-a17b — MoE. 48L d_model=5120 40H (GQA kv=8)
vocab=202048, MoE 128 experts top-1 (+ shared expert), early fusion.
[hf:meta-llama/Llama-4]

Interpretation note (DESIGN.md §4): routed experts use d_ff=8192 (as
assigned) and MoE layers interleave with dense layers (every 2nd layer,
dense d_ff=16384) plus one always-on shared expert per MoE layer — this is
the published Maverick layout and is required to land at ~400B total /
~17B active parameters.  Optimizer moments are kept in bf16 so the
train_4k cell fits 16 GB/chip HBM at 256 chips (ZeRO-3 over data axis).
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    d_ff=16384,                    # dense interleave layers
    vocab_size=202048,
    head_dim=128,
    mlp_variant="swiglu",
    rope_theta=500000.0,
    attn_pattern="global",
    tie_embeddings=True,
    moe=MoEConfig(num_experts=128, top_k=1, expert_ff=8192,
                  shared_expert_ff=8192, every_n_layers=2),
    optimizer_state_dtype="bfloat16",
)
