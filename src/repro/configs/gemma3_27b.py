"""gemma3-27b — dense. 62L d_model=5376 32H (GQA kv=16) d_ff=21504
vocab=262144, 5:1 local:global, 128k context. [hf:google/gemma-3]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-27b",
    family="dense",
    num_layers=62,
    d_model=5376,
    num_heads=32,
    num_kv_heads=16,
    d_ff=21504,
    vocab_size=262144,
    head_dim=128,
    mlp_variant="geglu",
    rope_theta=1000000.0,
    attn_pattern="local_global_5_1",
    window_size=1024,
    query_pre_attn_scalar=168.0,
    tie_embeddings=True,
)
