"""granite-moe-3b-a800m — MoE. 32L d_model=1536 24H (GQA kv=8) expert d_ff=512
vocab=49155, 40 experts top-8. [hf:ibm-granite/granite-3.0]
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    num_layers=32,
    d_model=1536,
    num_heads=24,
    num_kv_heads=8,
    d_ff=512,                       # per-expert ff
    vocab_size=49155,
    head_dim=64,
    mlp_variant="swiglu",
    rope_theta=10000.0,
    attn_pattern="global",
    tie_embeddings=True,
    moe=MoEConfig(num_experts=40, top_k=8, expert_ff=512, every_n_layers=1),
)
