"""mamba2-1.3b — SSM (attention-free). 48L d_model=2048 vocab=50280,
SSD (state-space duality), d_state=128, headdim=64, expand=2. [arXiv:2405.21060]
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    family="ssm",
    num_layers=48,
    d_model=2048,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    attn_pattern="global",
    tie_embeddings=True,
    ssm=SSMConfig(state_dim=128, head_dim=64, expand=2, conv_width=4,
                  chunk_size=256, ngroups=1),
)
