"""seamless-m4t-medium — audio enc-dec. 12L encoder + 12L decoder,
d_model=1024 16H d_ff=4096 vocab=256206. [arXiv:2308.11596]

The audio frontend (fbank/conformer feature extractor) is a STUB:
``input_specs`` provides precomputed frame embeddings for the encoder.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="audio",
    num_layers=12,                 # decoder layers
    num_encoder_layers=12,
    is_encoder_decoder=True,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=4096,
    vocab_size=256206,
    head_dim=64,
    mlp_variant="swiglu",
    rope_theta=10000.0,
    attn_pattern="global",
    tie_embeddings=True,
    embedding_inputs=True,
)
