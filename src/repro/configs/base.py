"""Configuration dataclasses for models, shapes, meshes and runs.

Every assigned architecture is a ``ModelConfig``; every assigned input shape
is a ``ShapeConfig``.  The dry-run iterates the cross product.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    expert_ff: int
    shared_expert_ff: int = 0          # llama4: one always-on shared expert
    every_n_layers: int = 1            # llama4: MoE every 2nd layer
    router_dtype: str = "float32"


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    state_dim: int = 128               # N (d_state)
    head_dim: int = 64                 # P (headdim)
    expand: int = 2                    # d_inner = expand * d_model
    conv_width: int = 4
    chunk_size: int = 256              # SSD chunk length
    ngroups: int = 1


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                        # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None     # default d_model // num_heads
    # --- activation / norm flavour ---
    mlp_variant: str = "swiglu"        # swiglu | geglu
    norm_eps: float = 1e-6
    tie_embeddings: bool = True
    # --- attention flavour ---
    rope_theta: float = 10000.0
    mrope: bool = False                # qwen2-vl multimodal rope (sections)
    mrope_sections: Tuple[int, ...] = (16, 24, 24)
    attn_pattern: str = "global"       # global | local_global_1_1 | local_global_5_1
    window_size: int = 4096            # local-attn sliding window
    attn_logit_softcap: float = 0.0    # gemma2: 50.0
    final_logit_softcap: float = 0.0   # gemma2: 30.0
    query_pre_attn_scalar: Optional[float] = None
    # --- optional subsystems ---
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # hybrid (zamba2): one shared attention block applied every N mamba layers
    hybrid_attn_every: int = 0
    # enc-dec (seamless)
    is_encoder_decoder: bool = False
    num_encoder_layers: int = 0
    # modality frontend stub: inputs arrive as precomputed embeddings
    embedding_inputs: bool = False
    # which layers are SSM in a hybrid stack: "all" for pure ssm
    # --- dtypes ---
    dtype: str = "bfloat16"
    # training memory knob: bf16 adam moments for very large models (llama4)
    optimizer_state_dtype: str = "float32"
    # sharding knob (§Perf): pad attention heads up to this count so they
    # divide the model axis (kills the seq<->heads resharding ping-pong for
    # 40/24/12/8-head archs); 0 = off.  Padded head compute is wasted
    # (pad/heads ratio) but replaces per-layer [B,S,D] all-gathers.
    pad_heads: int = 0

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim is not None:
            return self.head_dim
        return self.d_model // self.num_heads if self.num_heads else 0

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    def layer_kind(self, i: int) -> str:
        """Return 'attn' | 'local_attn' | 'ssm' for layer i of the stack."""
        if self.family == "ssm":
            return "ssm"
        if self.family == "hybrid":
            # zamba2: mamba everywhere; shared attention block interleaved
            return "ssm"
        if self.attn_pattern == "local_global_1_1":
            return "local_attn" if i % 2 == 0 else "attn"
        if self.attn_pattern == "local_global_5_1":
            return "attn" if (i % 6) == 5 else "local_attn"
        return "attn"

    def is_moe_layer(self, i: int) -> bool:
        if self.moe is None:
            return False
        return (i % self.moe.every_n_layers) == (self.moe.every_n_layers - 1)

    # ---- parameter counting (used for 6ND roofline cross-check) ----
    def param_counts(self) -> dict:
        """Analytic parameter counts: total and active-per-token."""
        d, hd = self.d_model, self.resolved_head_dim
        attn = d * self.num_heads * hd * 2 + d * self.num_kv_heads * hd * 2
        dense_mlp = 0
        if self.d_ff:
            n_mats = 3 if self.mlp_variant in ("swiglu", "geglu") else 2
            dense_mlp = n_mats * d * self.d_ff
        ssm = 0
        if self.ssm is not None:
            din = self.ssm.expand * d
            nheads = din // self.ssm.head_dim
            # in_proj (z,x,B,C,dt) + out_proj + conv + A,D
            ssm = d * (2 * din + 2 * self.ssm.ngroups * self.ssm.state_dim + nheads)
            ssm += din * d + self.ssm.conv_width * (din + 2 * self.ssm.ngroups * self.ssm.state_dim)
            ssm += 2 * nheads
        total = 0
        active = 0
        n_stack = self.num_layers
        for i in range(n_stack):
            kind = self.layer_kind(i)
            if kind == "ssm":
                total += ssm
                active += ssm
                if self.family == "ssm":
                    continue
                if self.family == "hybrid":
                    continue
            if self.family in ("dense", "moe", "vlm", "audio"):
                total += attn
                active += attn
            if self.is_moe_layer(i):
                m = self.moe
                router = d * m.num_experts
                experts = m.num_experts * 3 * d * m.expert_ff
                shared = 3 * d * m.shared_expert_ff
                total += router + experts + shared
                active += router + m.top_k * 3 * d * m.expert_ff + shared
            elif self.family in ("dense", "moe", "vlm", "audio"):
                total += dense_mlp
                active += dense_mlp
        # zamba2 shared attention+mlp block counted once
        if self.family == "hybrid" and self.hybrid_attn_every:
            shared_block = attn + dense_mlp
            total += shared_block
            n_inv = self.num_layers // self.hybrid_attn_every
            active += shared_block * 0 + (attn + dense_mlp)  # active per fwd ~= n_inv uses of same weights
        if self.is_encoder_decoder:
            # decoder layers add cross-attention
            total += self.num_layers * attn  # cross-attn per decoder layer
            active += self.num_layers * attn
            total += self.num_encoder_layers * (attn + dense_mlp)
            active += self.num_encoder_layers * (attn + dense_mlp)
        emb = self.vocab_size * d
        total += emb if self.tie_embeddings else 2 * emb
        active += emb if self.tie_embeddings else 2 * emb
        return {"total": total, "active": active}


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                          # train | prefill | decode


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524288, 1, "decode")

ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)


def shape_by_name(name: str) -> ShapeConfig:
    for s in ALL_SHAPES:
        if s.name == name:
            return s
    raise KeyError(name)


def long_context_capable(cfg: ModelConfig) -> bool:
    """long_500k is defined for sub-quadratic archs: SSM/hybrid, and
    local-window archs whose local layers cap their KV at the window."""
    if cfg.family in ("ssm", "hybrid"):
        return True
    return cfg.attn_pattern in ("local_global_1_1", "local_global_5_1")


def applicable_shapes(cfg: ModelConfig):
    out = [TRAIN_4K, PREFILL_32K, DECODE_32K]
    if long_context_capable(cfg):
        out.append(LONG_500K)
    return out
