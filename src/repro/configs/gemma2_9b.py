"""gemma2-9b — dense. 42L d_model=3584 16H (GQA kv=8) d_ff=14336 vocab=256000,
local+global alternating (1:1), attention/final logit softcaps. [arXiv:2408.00118]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-9b",
    family="dense",
    num_layers=42,
    d_model=3584,
    num_heads=16,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=256000,
    head_dim=256,
    mlp_variant="geglu",
    rope_theta=10000.0,
    attn_pattern="local_global_1_1",
    window_size=4096,
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    query_pre_attn_scalar=224.0,   # d_model / num_heads
    tie_embeddings=True,
)
