"""Raptor-style redundant data parallelism and straggler-robust aggregation.

The paper's flight/preemption idea applied to the training step:

- **flight-masked gradients**: the ``pod`` axis (size F) is the flight axis.
  Dropping a dead or straggling pod's gradient contribution is expressed as
  a per-sample loss weight that is constant within each pod's batch shard —
  mathematically identical to a masked mean over per-pod gradients, but it
  lowers in pure global view with zero extra collectives.  The step succeeds
  while >=1 pod survives, reproducing the p^N job-failure curve (Fig 8) at
  step granularity; surviving-pod renormalisation keeps the gradient
  unbiased.

- **redundant microbatches**: at flight factor r, each microbatch is
  assigned to r pods in cyclically shifted order (§3.3.3, Table 3); the
  host adopts the first arrival per microbatch and zeroes the weights of
  late copies — speculation with preemption at the data-pipeline level.

- **k-of-n**: keep the k fastest pods per step (latency signal measured by
  the host), drop the rest.

``signals_to_weights`` converts per-pod health/latency into the [B] weight
vector consumed by ``loss_fn`` (``batch["loss_weight"]``).
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.configs.base import ModelConfig
from repro.training.optimizer import OptConfig
from repro.training.step import make_train_step


def signals_to_weights(global_batch: int, num_pods: int, *,
                       health: Optional[np.ndarray] = None,
                       latency: Optional[np.ndarray] = None,
                       k: Optional[int] = None) -> np.ndarray:
    """Per-sample weights [B] from per-pod signals [F].

    health: {0,1} per pod -> drop dead pods.
    latency + k: keep only the k fastest pods (straggler drop).
    """
    keep = np.ones(num_pods, dtype=np.float32)
    if health is not None:
        keep = keep * np.asarray(health, dtype=np.float32)
    if latency is not None and k is not None:
        order = np.argsort(np.asarray(latency))
        mask = np.zeros(num_pods, np.float32)
        mask[order[:k]] = 1.0
        keep = keep * mask
    if keep.sum() == 0:
        raise RuntimeError(
            "all flight members failed — job failure (p^N event); "
            "restart from checkpoint")
    per_pod = global_batch // num_pods
    return np.repeat(keep, per_pod)


def redundant_assignment(num_micro: int, flight: int) -> list:
    """Microbatch -> list of pods computing it, with cyclic shift.

    With flight=r, each microbatch lands on r pods whose positions in their
    local order differ (decorrelated stragglers).  Returns
    [(micro, pod, position)] tuples.
    """
    out = []
    for pod in range(flight):
        order = list(range(num_micro))
        s = pod % max(num_micro, 1)
        order = order[s:] + order[:s]
        for pos, m in enumerate(order):
            out.append((m, pod, pos))
    return out


def first_arrival_weights(num_micro: int, flight: int,
                          arrival_times: np.ndarray) -> np.ndarray:
    """arrival_times: [flight, num_micro] host-observed completion times of
    each redundant copy.  Weight 1 for the first copy of each microbatch,
    0 for preempted duplicates."""
    w = np.zeros((flight, num_micro), np.float32)
    winners = np.argmin(arrival_times, axis=0)
    w[winners, np.arange(num_micro)] = 1.0
    return w


def make_raptor_train_step(cfg: ModelConfig, oc: OptConfig, *, constrain,
                           ep=None, remat: bool = True):
    """Identical signature to the plain step; flight behaviour enters purely
    through ``batch["loss_weight"]`` built by ``signals_to_weights``."""
    from repro.training.step import StepOptions
    return make_train_step(cfg, oc, constrain=constrain, ep=ep,
                           options=StepOptions(remat=remat))
