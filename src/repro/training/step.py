"""Train-step builder: loss -> grad -> (optional raptor k-of-n / compression)
-> AdamW.  Returns pure functions suitable for jit/lower on any mesh."""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import transformer as tfm
from repro.training.optimizer import OptConfig, adamw_update, init_opt_state


@dataclasses.dataclass(frozen=True)
class StepOptions:
    remat: bool = True
    remat_policy: Optional[str] = None       # None(full) | "dots"
    grad_compression: Optional[str] = None   # None | "bf16" | "int8"
    raptor_k_of_n: Optional[tuple] = None    # (k, axis_name) straggler drop


def make_loss_fn(cfg: ModelConfig, constrain=tfm._ID, remat: bool = True,
                 ep=None, remat_policy: Optional[str] = None):
    policy = None
    if remat_policy == "dots":
        policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable

    def loss(params, batch):
        return tfm.loss_fn(params, cfg, batch, constrain=constrain,
                           remat=remat, ep=ep, remat_policy=policy)
    return loss


def make_train_step(cfg: ModelConfig, oc: OptConfig, *, constrain=tfm._ID,
                    options: StepOptions = StepOptions(),
                    grad_transform: Optional[Callable] = None, ep=None):
    """Returns train_step(state, batch) -> (state, metrics).

    state = {"params": ..., "opt": ...}.  ``grad_transform(grads)`` is the
    injection point for Raptor k-of-n selection / compression (see
    repro.training.raptor_dp and repro.distributed.collectives).
    """
    loss_fn = make_loss_fn(cfg, constrain, options.remat, ep=ep,
                           remat_policy=options.remat_policy)

    def train_step(state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(state["params"], batch)
        if grad_transform is not None:
            grads = grad_transform(grads)
        params, opt, opt_metrics = adamw_update(
            grads, state["opt"], state["params"], oc)
        m = {"loss": loss, **metrics, **opt_metrics}
        return {"params": params, "opt": opt}, m

    return train_step


def init_train_state(cfg: ModelConfig, oc: OptConfig, key):
    params = tfm.init_params(cfg, key)
    return {"params": params, "opt": init_opt_state(params, oc)}


def train_state_shape(cfg: ModelConfig, oc: OptConfig):
    """ShapeDtypeStruct pytree of the train state (no allocation)."""
    return jax.eval_shape(
        partial(init_train_state, cfg, oc), jax.random.key(0))
