"""AdamW with cosine schedule, gradient clipping, and ZeRO-friendly layout.

Pure-pytree implementation (no optax dependency).  Moments are stored in
``cfg.optimizer_state_dtype`` — bf16 for the 400B llama4 config so the
train_4k cell fits HBM (DESIGN.md §4); update math is always f32.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    state_dtype: str = "float32"


def lr_at(step, oc: OptConfig):
    step = step.astype(jnp.float32)
    warm = oc.lr * (step + 1) / max(oc.warmup_steps, 1)
    t = jnp.clip((step - oc.warmup_steps)
                 / max(oc.total_steps - oc.warmup_steps, 1), 0.0, 1.0)
    cos = 0.1 * oc.lr + 0.9 * oc.lr * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return jnp.where(step < oc.warmup_steps, warm, cos)


def init_opt_state(params, oc: OptConfig) -> Dict[str, Any]:
    dt = jnp.dtype(oc.state_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree):
    leaves = [jnp.sum(jnp.square(l.astype(jnp.float32)))
              for l in jax.tree_util.tree_leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(grads, opt_state, params, oc: OptConfig):
    """Returns (new_params, new_opt_state, metrics)."""
    step = opt_state["step"]
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, oc.clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = lr_at(step, oc)
    t = (step + 1).astype(jnp.float32)
    bc1 = 1 - oc.b1 ** t
    bc2 = 1 - oc.b2 ** t
    sdt = jnp.dtype(oc.state_dtype)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32 = oc.b1 * m.astype(jnp.float32) + (1 - oc.b1) * g
        v32 = oc.b2 * v.astype(jnp.float32) + (1 - oc.b2) * jnp.square(g)
        mhat = m32 / bc1
        vhat = v32 / bc2
        delta = mhat / (jnp.sqrt(vhat) + oc.eps)
        if p.ndim >= 2:   # decoupled weight decay on matrices only
            delta = delta + oc.weight_decay * p.astype(jnp.float32)
        newp = p.astype(jnp.float32) - lr * delta
        return newp.astype(p.dtype), m32.astype(sdt), v32.astype(sdt)

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_m = jax.tree_util.tree_leaves(opt_state["mu"])
    flat_v = jax.tree_util.tree_leaves(opt_state["nu"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(treedef, [o[2] for o in out])
    new_state = {"mu": new_m, "nu": new_v, "step": step + 1}
    return new_p, new_state, {"grad_norm": gnorm, "lr": lr}
