"""Roofline analysis from the dry-run's compiled artifacts (EXPERIMENTS.md
§Roofline).

Terms (per chip; cost_analysis() is per-device on this jax build — verified
by probe, DESIGN.md §6):

    compute    = HLO_FLOPs_visible + scan-hidden FLOPs   / 197e12  (bf16 peak)
    memory     = HLO_bytes * bf16_adjust                 / 819e9   (HBM bw)
    collective = sum ring_factor(op) * op_bytes          / 50e9    (ICI link)

Corrections, both documented in EXPERIMENTS.md:
- scan-hidden FLOPs: cost_analysis counts a lax.scan body ONCE; the only
  scanned compute in the models is blockwise prefill attention (S=32k), so
  the analytic attention FLOPs x (nk-1)/nk are added back.
- bf16_adjust = 0.5 for bf16-dominated programs: the CPU backend upcasts
  bf16->f32, doubling every byte count relative to the TPU target.
"""
from __future__ import annotations

import json
import sys
from typing import Dict, Optional

from repro.configs import get_config, shape_by_name
from repro.configs.base import ModelConfig, ShapeConfig

PEAK_FLOPS = 197e12          # bf16 / chip (TPU v5e)
HBM_BW = 819e9               # bytes/s / chip
LINK_BW = 50e9               # bytes/s / ICI link
BF16_ADJUST = 0.5            # CPU-HLO f32 upcast correction for bytes

RING = {                     # per-device ring-cost factors (n=16 axis)
    "all-reduce": 2 * 15 / 16,
    "all-gather": 15 / 16,
    "reduce-scatter": 15 / 16,
    "all-to-all": 15 / 16,
    "collective-permute": 1.0,
}

KV_CHUNK = 1024
BLOCKWISE_THRESHOLD = 8192


def attention_flops_per_device(cfg: ModelConfig, shape: ShapeConfig,
                               chips: int) -> float:
    """Analytic causal-attention FLOPs for global-attn layers (QK^T + AV)."""
    if cfg.num_heads == 0:
        return 0.0
    b, s = shape.global_batch, shape.seq_len
    hd = cfg.resolved_head_dim
    n_global = sum(1 for i in range(cfg.num_layers)
                   if cfg.layer_kind(i) == "attn")
    if cfg.is_encoder_decoder:
        n_global += cfg.num_encoder_layers
    flops = n_global * 2 * 2 * b * cfg.num_heads * (s * s / 2) * hd
    return flops / chips


def hidden_flops(cfg: ModelConfig, shape: ShapeConfig, chips: int,
                 kind: str) -> float:
    """FLOPs invisible to cost_analysis (scan bodies counted once)."""
    if shape.kind != "prefill" or shape.seq_len <= BLOCKWISE_THRESHOLD:
        return 0.0
    nk = shape.seq_len // KV_CHUNK
    att = attention_flops_per_device(cfg, shape, chips)
    # forward-only prefill; scan shows 1/nk of the attention math
    return att * (nk - 1) / nk


def model_flops_per_device(cfg: ModelConfig, shape: ShapeConfig,
                           chips: int) -> float:
    """6*N_active*D (train) or 2*N_active*tokens (inference)."""
    n_active = cfg.param_counts()["active"]
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6 * n_active * tokens / chips
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2 * n_active * tokens / chips
    tokens = shape.global_batch          # one new token per sequence
    return 2 * n_active * tokens / chips


def analyze_record(rec: Dict) -> Optional[Dict]:
    if not rec.get("ok"):
        return None
    cfg = get_config(rec["arch"])
    shape = shape_by_name(rec["shape"])
    chips = 512 if rec.get("mesh", "").startswith("2x") else 256
    visible = rec["flops_per_device"]
    hidden = hidden_flops(cfg, shape, chips, shape.kind)
    flops = visible + hidden
    t_compute = flops / PEAK_FLOPS
    mem_bytes = rec["bytes_per_device"] * BF16_ADJUST
    t_memory = mem_bytes / HBM_BW
    coll = rec.get("collective_bytes", {})
    # BF16_ADJUST applies to collectives too: the CPU backend upcasts bf16
    # tensors to f32, so parsed operand sizes are 2x the TPU transfer size.
    coll_bytes = sum(RING.get(op, 1.0) * b for op, b in coll.items()) \
        * BF16_ADJUST
    t_coll = coll_bytes / LINK_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())
    mf = model_flops_per_device(cfg, shape, chips)
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_coll, "dominant": dominant,
        "hlo_flops": flops, "hidden_flops": hidden,
        "model_flops": mf,
        "useful_ratio": mf / flops if flops else 0.0,
        "roofline_fraction": t_compute / bound if bound else 0.0,
        "peak_gib": rec.get("peak_bytes_per_device", 0) / 2**30,
    }


def table(results_path: str = "dryrun_results.json",
          mesh_filter: str = "16x16") -> list:
    with open(results_path) as f:
        rows = json.load(f)
    out = []
    for rec in rows:
        if mesh_filter and rec.get("mesh") != mesh_filter:
            continue
        r = analyze_record(rec)
        if r:
            out.append(r)
    return out


def main():
    path = sys.argv[1] if len(sys.argv) > 1 else "dryrun_results.json"
    rows = table(path)
    hdr = (f"{'arch':26s} {'shape':12s} {'compute_s':>10s} {'memory_s':>10s} "
           f"{'coll_s':>10s} {'dom':>10s} {'useful':>7s} {'roofl%':>7s}")
    print(hdr)
    for r in rows:
        print(f"{r['arch']:26s} {r['shape']:12s} {r['t_compute_s']:10.4f} "
              f"{r['t_memory_s']:10.4f} {r['t_collective_s']:10.4f} "
              f"{r['dominant']:>10s} {r['useful_ratio']:7.2f} "
              f"{100*r['roofline_fraction']:6.1f}%")


if __name__ == "__main__":
    main()
