"""Gate on BENCH_sim.json throughput regressions.

    python benchmarks/check_regression.py BASELINE.json MEASURED.json \
        [--factor 5]

Compares the vectorized-sim throughput numbers of a fresh benchmark run
against the checked-in baseline and exits non-zero when any tracked metric
regressed by more than ``factor`` (default 5x — wide enough to absorb
runner-class differences between the laptop that recorded the baseline and
a shared CI box, narrow enough to catch an accidental de-vectorization,
which costs 50-150x).  Metrics missing from either file are skipped, so the
gate tolerates schema growth in both directions.
"""
from __future__ import annotations

import argparse
import json
import sys

# (path into the record, human label)
TRACKED = [
    (("vector", "trials_per_s"), "open-loop vector trials/s"),
    (("queue", "jobs_per_s"), "closed-loop queue jobs/s"),
    (("dag_wordcount", "jobs_per_s"), "wordcount DAG jobs/s"),
    (("fig6_sweep", "vector_jobs_per_s"), "fig6 load-sweep jobs/s"),
]


def _get(record: dict, path):
    for key in path:
        if not isinstance(record, dict) or key not in record:
            return None
        record = record[key]
    return record


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("baseline")
    ap.add_argument("measured")
    ap.add_argument("--factor", type=float, default=5.0,
                    help="fail when baseline/measured exceeds this")
    args = ap.parse_args()
    with open(args.baseline) as f:
        base = json.load(f)
    with open(args.measured) as f:
        meas = json.load(f)

    failed = False
    for path, label in TRACKED:
        b, m = _get(base, path), _get(meas, path)
        if b is None or m is None:
            print(f"skip  {label}: missing "
                  f"({'baseline' if b is None else 'measured'})")
            continue
        ratio = b / m if m else float("inf")
        status = "FAIL" if ratio > args.factor else "ok"
        failed |= status == "FAIL"
        print(f"{status:5s} {label}: baseline={b:.0f} measured={m:.0f} "
              f"(slowdown {ratio:.2f}x, limit {args.factor:.1f}x)")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
