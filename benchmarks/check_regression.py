"""Gate on BENCH_sim.json throughput regressions.

    python benchmarks/check_regression.py BASELINE.json MEASURED.json \
        [--factor 2.5]

Compares the vectorized-sim throughput numbers of a fresh benchmark run
against the checked-in baseline and exits non-zero when any tracked metric
regressed by more than ``factor`` (default 2.5x — two PRs of GH-runner
numbers showed run-to-run spread well under 2x vs the recording box, and
the failure mode the gate exists for, an accidental de-vectorization,
costs 50-150x).

Missing-tier semantics: a tracked metric absent from the BASELINE is a
brand-new tier — an explicit, printed PASS-with-note (the gate has no
reference yet; the regenerated baseline picks it up next PR).  A tracked
metric absent from the MEASURED file is a hard failure: the tier silently
fell out of the bench run, which is exactly the kind of coverage rot a
gate exists to catch.
"""
from __future__ import annotations

import argparse
import json
import sys

# (path into the record, human label)
TRACKED = [
    (("vector", "trials_per_s"), "open-loop vector trials/s"),
    (("queue", "jobs_per_s"), "closed-loop queue (oracle) jobs/s"),
    (("queue_blocked", "jobs_per_s"), "blocked event-replay queue jobs/s"),
    (("queue_logdepth", "jobs_per_s"), "log-depth summary-chain queue jobs/s"),
    (("dag_wordcount", "jobs_per_s"), "wordcount DAG jobs/s"),
    (("dag_manifest", "jobs_per_s"), "compiled-manifest ETL DAG jobs/s"),
    (("queue_stock_taskfcfs", "jobs_per_s"), "task-FCFS stock jobs/s"),
    (("queue_faults", "jobs_per_s"), "fault-injected queue jobs/s"),
    (("queue_streaming", "jobs_per_s"), "streaming open-load queue jobs/s"),
    (("fig6_sweep", "vector_jobs_per_s"), "fig6 load-sweep jobs/s"),
    (("sweep_sharded", "jobs_per_s"), "device-sharded sweep-grid jobs/s"),
]


def _get(record: dict, path):
    for key in path:
        if not isinstance(record, dict) or key not in record:
            return None
        record = record[key]
    return record


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("baseline")
    ap.add_argument("measured")
    ap.add_argument("--factor", type=float, default=2.5,
                    help="fail when baseline/measured exceeds this")
    args = ap.parse_args()
    with open(args.baseline) as f:
        base = json.load(f)
    with open(args.measured) as f:
        meas = json.load(f)

    failures = []
    for path, label in TRACKED:
        b, m = _get(base, path), _get(meas, path)
        if m is None:
            print(f"FAIL  {label}: missing from the measured run "
                  f"(tier dropped out of the bench job)")
            failures.append((label, b if b is not None else float("nan"),
                             0.0, float("inf")))
            continue
        if b is None:
            print(f"PASS  {label}: new tier, no baseline yet "
                  f"(measured={m:.0f}; gate starts next regeneration)")
            continue
        ratio = b / m if m else float("inf")
        status = "FAIL" if ratio > args.factor else "ok"
        if status == "FAIL":
            failures.append((label, b, m, ratio))
        print(f"{status:5s} {label}: baseline={b:.0f} measured={m:.0f} "
              f"(slowdown {ratio:.2f}x, limit {args.factor:.1f}x)")
    if failures:
        print(f"\n{len(failures)} tracked tier(s) regressed past "
              f"{args.factor:.1f}x:", file=sys.stderr)
        for label, b, m, ratio in failures:
            print(f"  {label}: {b:.0f}/s -> {m:.0f}/s "
                  f"({ratio:.2f}x slower)", file=sys.stderr)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
