"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows — us_per_call is the harness
wall time per simulated/served job; derived is the table's headline metric.

    PYTHONPATH=src python -m benchmarks.run [--fast]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time


def _row(name, us, derived):
    print(f"{name},{us:.1f},{derived}")
    sys.stdout.flush()


def enable_compile_cache() -> str:
    """Point jax at a persistent on-disk compilation cache.

    The vectorized sim's XLA compiles (~1.5s-15s each, BENCH_sim.json
    compile_cold_s) dominate short benches; with the cache they amortise
    across processes/CI runs (compile_warm_s).  Safe to call before any
    jax computation; returns the cache dir.
    """
    cache_dir = os.environ.get(
        "JAX_COMPILATION_CACHE_DIR",
        os.path.join(os.path.dirname(os.path.abspath(__file__)),
                     ".jax_cache"))
    import jax
    jax.config.update("jax_compilation_cache_dir", cache_dir)
    jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.0)
    try:
        jax.config.update("jax_persistent_cache_min_entry_size_bytes", -1)
    except Exception:
        pass                      # older jax: size gate stays at default
    return cache_dir


def bench_table6_overhead():
    from repro.sim.experiments import table6_overhead
    t0 = time.time()
    rows = table6_overhead(n=20000)
    us = (time.time() - t0) * 1e6 / (6 * 20000)
    med = rows["three_az/medium"]
    _row("table6_overhead", us,
         f"3az_medium_median={med['median']:.1f}ms_p90={med['p90']:.1f}ms"
         f"_paper=9/16ms")


def bench_table7_keygen(dur):
    from repro.sim.experiments import table7_keygen
    t0 = time.time()
    r = table7_keygen(duration_s=dur)
    n = r["stock"]["n"] + r["raptor"]["n"]
    us = (time.time() - t0) * 1e6 / max(n, 1)
    _row("table7_keygen", us,
         f"stock_mean={r['stock']['mean']:.0f}ms"
         f"_raptor_mean={r['raptor']['mean']:.0f}ms"
         f"_ratio={r['mean_ratio']:.3f}_paper=0.647_theory=0.667")


def bench_fig6_scale(dur):
    from repro.sim.experiments import fig6_scale_effect
    t0 = time.time()
    out = fig6_scale_effect(duration_s=dur)
    us = (time.time() - t0) * 1e6 / sum(
        v["stock"]["n"] + v["raptor"]["n"] for v in out.values())
    # the 1-AZ point is compared at low load: at 5 workers a flight of 2
    # doubles per-job worker demand, so "moderate" load queues — the effect
    # the paper notes as Kafka-queue domination at high load (§4.2.1)
    _row("fig6_scale_effect", us,
         f"one_az_low_ratio={out['one_az_5w/low']['mean_ratio']:.3f}"
         f"_one_az_med_ratio={out['one_az_5w/medium']['mean_ratio']:.3f}"
         f"_three_az_ratio={out['three_az_15w/medium']['mean_ratio']:.3f}"
         f"_paper=0.99/na/0.65")


def bench_fig7_workloads(dur):
    from repro.sim.experiments import fig7_other_workloads
    t0 = time.time()
    out = fig7_other_workloads(duration_s=dur)
    n = sum(v["stock"]["n"] + v["raptor"]["n"] for v in out.values())
    us = (time.time() - t0) * 1e6 / max(n, 1)
    _row("fig7_wordcount", us,
         f"ratio={out['wordcount']['mean_ratio']:.3f}_paper=0.455")
    _row("fig7_thumbnail", us,
         f"ratio={out['thumbnail']['mean_ratio']:.3f}_paper=0.892")


def bench_fig8_reliability(dur):
    from repro.sim.experiments import fig8_reliability
    t0 = time.time()
    out = fig8_reliability(n_jobs_s=dur)
    us = (time.time() - t0) * 1e6 / max(len(out), 1)
    r = out["n4/p0.2"]
    _row("fig8_reliability", us,
         f"n4_p0.2_stock={r['stock_fail']:.3f}(theory={r['theory_stock']:.3f})"
         f"_raptor={r['raptor_fail']:.4f}(exact={r['theory_raptor_exact']:.4f})")


def _scalar_jobs_per_s(wl_fn, deployment, load, n_jobs, *, raptor=True,
                       seed=0):
    """Event-driven oracle throughput on one config, sized to ~n_jobs."""
    from repro.sim.cluster import Cluster
    from repro.sim.experiments import rate_for
    from repro.sim.flights import FlightSim
    wl = wl_fn()
    rate = rate_for(wl, deployment, load)
    sim = FlightSim(Cluster(seed=seed, **deployment), wl, raptor=raptor,
                    arrival_rate_hz=rate, duration_s=n_jobs / rate,
                    load=load, seed=seed)
    t0 = time.time()
    jobs = sim.run()
    return len(jobs), time.time() - t0


def bench_sim_vector(trials: int = 10000):
    """Vectorized MC sim vs the scalar event-driven FlightSim, per tier:

    * open_loop — the PR-1 zero-queueing batch (Table-7 keygen config);
    * queue     — the closed-loop M/G/c engine on the SEQUENTIAL ORACLE
                  path (block=1: plain event scan, conservative race
                  budget — bit-for-bit the pre-blocking engine), cold vs
                  warm compile recorded (persistent cache);
    * queue_blocked — the same workload/jobs/trials on the blocked
                  event-replay core (sim/scan_core.py) at its auto
                  config: chunked replay + tight K-completion races,
                  results bitwise equal to the oracle (checked in-bench);
    * queue_logdepth — the same shape through the associative max-plus
                  summary chain (scan="logdepth", adaptive split), bitwise
                  the oracle; honest host number — the mode is work-bound
                  on CPUs (EXPERIMENTS.md §log-depth);
    * dag       — the wordcount DAG manifest through the dependency-masked
                  flight scan, closed loop at medium load (blocked core);
    * queue-stock-taskfcfs — the task-granular stock replay (wordcount
                  STOCK at util 0.75), ≥20x the scalar oracle;
    * queue_streaming — the open-arrival streaming scheduler service
                  (sim/streaming.py): one MMPP stream microbatched onto
                  the persistent device-resident W-state — SUSTAINED
                  jobs/s plus p50/p99 sojourn and SLO-violation fraction
                  under open load, bitwise-checked against the
                  whole-trace block=1 oracle in-bench;
    * sweep-sharded — the closed-loop utilisation grid through the
                  device-sharded SweepPlan driver (sim/sweeps.py), all
                  (forced-host) devices vs one: ≥2x grid throughput on a
                  4-device host, summaries bit-identical.

    Every closed-loop tier records compile_cold_s/compile_warm_s.  The
    metric is jobs/sec at matched job counts; results land in
    BENCH_sim.json so CI can gate on regressions (benchmarks/
    check_regression.py).
    """
    import jax
    import numpy as np
    from repro.sim.experiments import HA
    from repro.sim.faults import FaultProfile
    from repro.sim.policies import RecoveryPolicy
    from repro.sim.vector import VectorFlightSim, keygen_vector
    from repro.sim.vector_queue import (QueueFlightSim, keygen_queue,
                                        load_sweep, wordcount_queue)
    from repro.sim.workloads import keygen_workload, wordcount_workload

    record = {"trials": trials}
    # the PR-4 recording's queue tier (the engine the blocked core
    # replaced), pinned as a constant so the provenance anchor cannot
    # drift when this run overwrites BENCH_sim.json: every regeneration
    # reports the blocked core's speedup against the same seed number
    prior_queue_tps = 378886.96846149676
    # the PR-5 recording's queue_blocked tier — the ISSUE-6 acceptance
    # anchor for the log-depth chain, pinned for the same reason
    prior_blocked_tps = 948490.4927918591

    # ---- open loop (legacy layout: top-level scalar/vector/speedup) ----
    n_jobs, scalar_s = _scalar_jobs_per_s(keygen_workload, HA, "medium",
                                          trials)
    scalar_tps = n_jobs / scalar_s
    vec = VectorFlightSim(keygen_vector(), num_azs=3, flight=2, seed=0)
    t0 = time.time()
    vec.run(trials, raptor=True).response_ms.block_until_ready()
    compile_s = time.time() - t0
    # best-of-reps: the box runs other work, and one stalled rep would
    # otherwise report a phantom regression to the CI gate
    reps = 5

    def best_of(fn):
        best = float("inf")
        for _ in range(reps):
            t0 = time.time()
            fn()
            best = min(best, time.time() - t0)
        return best

    res = vec.run(trials, raptor=True)
    vector_s = best_of(
        lambda: vec.run(trials, raptor=True).response_ms.block_until_ready())
    vector_tps = trials / vector_s
    record["scalar"] = {"jobs": n_jobs, "wall_s": scalar_s,
                        "trials_per_s": scalar_tps}
    record["vector"] = {"wall_s": vector_s, "compile_s": compile_s,
                        "trials_per_s": vector_tps,
                        "mean_ms": res.summary()["mean"]}
    record["speedup"] = vector_tps / scalar_tps
    _row("sim_vector", vector_s * 1e6 / trials,
         f"scalar={scalar_tps:.0f}t/s_vector={vector_tps:.0f}t/s"
         f"_speedup={record['speedup']:.0f}x_target>=50x")

    def cold_warm(run):
        """Cold compile, then warm (in-memory exes dropped, persistent
        disk cache hot) — recorded for every closed-loop tier."""
        t0 = time.time()
        out = run()
        out.response_ms.block_until_ready()
        cold = time.time() - t0
        jax.clear_caches()        # drop in-memory exe; reload from disk
        t0 = time.time()
        run().response_ms.block_until_ready()
        return out, cold, time.time() - t0

    # ---- closed-loop queue: the sequential ORACLE path (block=1) -------
    # block=1 pins the plain event scan with the conservative full race
    # budget — bit-for-bit the pre-blocking engine, the configuration the
    # blocked core is verified against (tests/test_queue_properties.py)
    q_jobs = max(trials // 8, 256)
    q_trials = 48
    qsim = QueueFlightSim(keygen_queue(), load="medium", seed=0, block=1,
                          **HA)
    r, cold_s, warm_s = cold_warm(
        lambda: qsim.run(q_jobs, q_trials, raptor=True))
    q_wall = best_of(
        lambda: qsim.run(q_jobs, q_trials,
                         raptor=True).response_ms.block_until_ready())
    q_tps = q_jobs * q_trials / q_wall
    sn, ss = _scalar_jobs_per_s(keygen_workload, HA, "medium",
                                min(q_jobs * q_trials, 8192))
    record["queue"] = {
        "vector_jobs": q_jobs * q_trials, "wall_s": q_wall,
        "jobs_per_s": q_tps, "compile_cold_s": cold_s,
        "compile_warm_s": warm_s,
        "scalar_jobs_per_s": sn / ss, "speedup": q_tps / (sn / ss),
        "mean_ms": r.summary()["mean"],
    }
    _row("sim_queue", q_wall * 1e6 / (q_jobs * q_trials),
         f"scalar={sn/ss:.0f}j/s_vector={q_tps:.0f}j/s"
         f"_speedup={q_tps/(sn/ss):.0f}x_cold={cold_s:.1f}s"
         f"_warm={warm_s:.2f}s_target>=50x")

    # ---- queue_blocked: the blocked event-replay core, same shape ------
    # same workload at EQUAL jobs/trials on the blocked substrate's auto
    # config (chunked replay + tight K-completion race budget); responses
    # must be bitwise the oracle's, and the acceptance anchor is the
    # speedup over the seed recording's queue tier (>= 2x)
    bsim = QueueFlightSim(keygen_queue(), load="medium", seed=0, **HA)
    rb, b_cold, b_warm = cold_warm(
        lambda: bsim.run(q_jobs, q_trials, raptor=True))
    b_wall = best_of(
        lambda: bsim.run(q_jobs, q_trials,
                         raptor=True).response_ms.block_until_ready())
    b_tps = q_jobs * q_trials / b_wall
    blk, res_mode, _ = bsim.engine_config("raptor")
    exact = bool(np.array_equal(np.asarray(rb.response_ms),
                                np.asarray(r.response_ms)))
    record["queue_blocked"] = {
        "vector_jobs": q_jobs * q_trials, "wall_s": b_wall,
        "jobs_per_s": b_tps, "compile_cold_s": b_cold,
        "compile_warm_s": b_warm, "block": blk, "resolver": res_mode,
        "bitwise_equals_oracle": exact,
        "vs_queue_oracle": b_tps / q_tps,
        "baseline_queue_jobs_per_s": prior_queue_tps,
        "speedup_vs_baseline_queue": (
            b_tps / prior_queue_tps if prior_queue_tps else None),
        "mean_ms": rb.summary()["mean"],
    }
    base_txt = (f"_vs_seed={b_tps / prior_queue_tps:.2f}x"
                if prior_queue_tps else "")
    _row("sim_queue_blocked", b_wall * 1e6 / (q_jobs * q_trials),
         f"oracle={q_tps:.0f}j/s_blocked={b_tps:.0f}j/s"
         f"_x{b_tps/q_tps:.2f}{base_txt}_block={blk}/{res_mode}"
         f"_bitwise={exact}_cold={b_cold:.1f}s_warm={b_warm:.2f}s"
         f"_target>=2x_vs_seed")

    # ---- queue_logdepth: the associative max-plus summary chain --------
    # same workload at EQUAL jobs/trials with scan="logdepth" (block 0 =
    # the adaptive ceil(n/3) split); responses must stay bitwise the
    # oracle's.  The ISSUE-6 acceptance target was the PR-5 queue_blocked
    # recording, but the mode is work-bound on hosts: the block-level
    # Jacobi gains exactly ONE exact block per outer pass in every load
    # regime (worker choice is bitwise-coupled to the entry vector), so
    # nb blocks cost nb x the bookings and the host optimum (nb=2 + tail)
    # still pays ~1.7x the sequential chain's work.  The honest number is
    # recorded as-is; the mode's value is depth, not host throughput
    # (EXPERIMENTS.md §log-depth).
    lsim = QueueFlightSim(keygen_queue(), load="medium", seed=0,
                          scan="logdepth", **HA)
    rl, l_cold, l_warm = cold_warm(
        lambda: lsim.run(q_jobs, q_trials, raptor=True))
    l_wall = best_of(
        lambda: lsim.run(q_jobs, q_trials,
                         raptor=True).response_ms.block_until_ready())
    l_tps = q_jobs * q_trials / l_wall
    l_blk, l_res, l_scan = lsim.engine_config("raptor")
    l_exact = bool(np.array_equal(np.asarray(rl.response_ms),
                                  np.asarray(r.response_ms)))
    record["queue_logdepth"] = {
        "vector_jobs": q_jobs * q_trials, "wall_s": l_wall,
        "jobs_per_s": l_tps, "compile_cold_s": l_cold,
        "compile_warm_s": l_warm, "block": l_blk, "resolver": l_res,
        "scan": l_scan, "bitwise_equals_oracle": l_exact,
        "vs_queue_blocked": l_tps / b_tps,
        "baseline_blocked_jobs_per_s": prior_blocked_tps,
        "beats_baseline_blocked": bool(l_tps > prior_blocked_tps),
        "mean_ms": rl.summary()["mean"],
    }
    _row("sim_queue_logdepth", l_wall * 1e6 / (q_jobs * q_trials),
         f"blocked={b_tps:.0f}j/s_logdepth={l_tps:.0f}j/s"
         f"_x{l_tps/b_tps:.2f}_block={l_blk}/{l_res}"
         f"_bitwise={l_exact}_cold={l_cold:.1f}s_warm={l_warm:.2f}s"
         f"_host_workbound")

    # ---- DAG workload (wordcount) through the dep-masked scan ----------
    d_jobs, d_trials = max(trials // 16, 128), 16
    dsim = QueueFlightSim(wordcount_queue(), load="medium", seed=0, **HA)
    r, d_cold, d_warm = cold_warm(
        lambda: dsim.run(d_jobs, d_trials, raptor=True))
    d_wall = best_of(
        lambda: dsim.run(d_jobs, d_trials,
                         raptor=True).response_ms.block_until_ready())
    d_tps = d_jobs * d_trials / d_wall
    sn, ss = _scalar_jobs_per_s(wordcount_workload, HA, "medium",
                                min(d_jobs * d_trials, 4096))
    record["dag_wordcount"] = {
        "vector_jobs": d_jobs * d_trials, "jobs_per_s": d_tps,
        "compile_cold_s": d_cold, "compile_warm_s": d_warm,
        "scalar_jobs_per_s": sn / ss, "speedup": d_tps / (sn / ss),
        "mean_ms": r.summary()["mean"],
    }
    _row("sim_dag", d_wall * 1e6 / (d_jobs * d_trials),
         f"scalar={sn/ss:.0f}j/s_vector={d_tps:.0f}j/s"
         f"_speedup={d_tps/(sn/ss):.0f}x_cold={d_cold:.1f}s"
         f"_warm={d_warm:.2f}s")

    # ---- dag_manifest: a compiled workload-bank graph, conditionals on -
    # The ETL pipeline straight from the workflow-manifest compiler
    # (core/workflow.py): wide transform fan-out behind a data-dependent
    # validate conditional (poison jobs detour to quarantine via the
    # mask-select path).  Tracks the compiler->engine route's throughput
    # at the auto blocked config, and pins the conditional scan's blocked
    # replay bitwise against the block=1 oracle in-bench — runs AND ok
    # bits (failure routing is the point of the graph).
    from repro.sim.vector_queue import etl_queue
    m_jobs, m_trials = max(trials // 32, 64), 8
    m_wl = etl_queue()
    msim = QueueFlightSim(m_wl, load="medium", seed=0, **HA)
    rm, m_cold, m_warm = cold_warm(
        lambda: msim.run(m_jobs, m_trials, raptor=True))
    m_wall = best_of(
        lambda: msim.run(m_jobs, m_trials,
                         raptor=True).response_ms.block_until_ready())
    m_tps = m_jobs * m_trials / m_wall
    m1sim = QueueFlightSim(m_wl, load="medium", seed=0, block=1, **HA)
    rm1 = m1sim.run(m_jobs, m_trials, raptor=True)
    m_exact = bool(
        np.array_equal(np.asarray(rm.response_ms),
                       np.asarray(rm1.response_ms))
        and np.array_equal(np.asarray(rm.ok), np.asarray(rm1.ok)))
    m_blk, m_res, _ = msim.engine_config("raptor")
    record["dag_manifest"] = {
        "graph": m_wl.graph.name, "manifest_hash": m_wl.graph.manifest_hash,
        "tasks": m_wl.graph.K, "vector_jobs": m_jobs * m_trials,
        "wall_s": m_wall, "jobs_per_s": m_tps,
        "compile_cold_s": m_cold, "compile_warm_s": m_warm,
        "block": m_blk, "resolver": m_res,
        "bitwise_equals_oracle": m_exact,
        "mean_ms": rm.summary()["mean"],
        "fail_rate": rm.summary()["fail_rate"],
    }
    _row("sim_dag_manifest", m_wall * 1e6 / (m_jobs * m_trials),
         f"etl={m_tps:.0f}j/s_block={m_blk}/{m_res}_bitwise={m_exact}"
         f"_cold={m_cold:.1f}s_warm={m_warm:.2f}s"
         f"_hash={m_wl.graph.manifest_hash}")

    # ---- queue-stock-taskfcfs: the task-granular stock engine ----------
    # wordcount STOCK at util 0.75 (load="high") — the regime the
    # task-FCFS rewrite made faithful (tests/test_sim_queue.py pins the
    # <10% mean/p99 agreement).  Benched at stock_extra_passes=0, the
    # minimal scan-over-stage-depth configuration (also fidelity-tested);
    # 256 jobs/trial keeps the queue in regime (~95s windows) while the
    # sequential event scan stays short, and the trial axis carries the
    # parallelism.
    tf_jobs, tf_trials = 256, max(trials // 80, 24)
    tfsim = QueueFlightSim(wordcount_queue(), load="high", seed=0,
                           stock_extra_passes=0, **HA)
    r, tf_cold, tf_warm = cold_warm(
        lambda: tfsim.run(tf_jobs, tf_trials, raptor=False))
    tf_wall = best_of(
        lambda: tfsim.run(tf_jobs, tf_trials,
                          raptor=False).response_ms.block_until_ready())
    tf_tps = tf_jobs * tf_trials / tf_wall
    sn, ss = _scalar_jobs_per_s(wordcount_workload, HA, "high",
                                min(tf_jobs * tf_trials, 4096),
                                raptor=False)
    record["queue_stock_taskfcfs"] = {
        "vector_jobs": tf_jobs * tf_trials, "jobs_per_s": tf_tps,
        "compile_cold_s": tf_cold, "compile_warm_s": tf_warm,
        "scalar_jobs_per_s": sn / ss, "speedup": tf_tps / (sn / ss),
        "mean_ms": r.summary()["mean"],
    }
    _row("sim_stock_taskfcfs", tf_wall * 1e6 / (tf_jobs * tf_trials),
         f"scalar={sn/ss:.0f}j/s_vector={tf_tps:.0f}j/s"
         f"_speedup={tf_tps/(sn/ss):.0f}x_cold={tf_cold:.1f}s"
         f"_warm={tf_warm:.2f}s_target>=20x")

    # ---- queue_faults: the attempt-expanded fault/policy path ----------
    # keygen under Markov-modulated AZ brownouts + worker crashes with a
    # timeout/retry/hedge recovery policy (sim/faults.py, sim/policies.py).
    # The attempt expansion multiplies the event stream by (1 + retries +
    # hedge), so this tier tracks the fault path's own throughput AND pins
    # its blocked-replay bitwise invariance against the block=1 oracle —
    # the same acceptance the fault property tests enforce.
    f_prof = FaultProfile(az_mtbf_ms=24_000.0, az_mttr_ms=6_000.0,
                          degraded_inflation=2.0, degraded_fail_prob=0.05,
                          crash_mtbf_ms=400_000.0, crash_restart_ms=2_000.0)
    f_pol = RecoveryPolicy(timeout_ms=6_000.0, max_retries=1,
                           backoff_ms=50.0, hedge_ms=2_500.0)
    f_jobs, f_trials = max(trials // 16, 128), 16
    fwl = keygen_queue(fail_prob=0.01, faults=f_prof, recovery=f_pol)
    fsim = QueueFlightSim(fwl, load="medium", seed=0, **HA)
    rf, f_cold, f_warm = cold_warm(
        lambda: fsim.run(f_jobs, f_trials, raptor=True))
    f_wall = best_of(
        lambda: fsim.run(f_jobs, f_trials,
                         raptor=True).response_ms.block_until_ready())
    f_tps = f_jobs * f_trials / f_wall
    f1sim = QueueFlightSim(fwl, load="medium", seed=0, block=1, **HA)
    rf1 = f1sim.run(f_jobs, f_trials, raptor=True)
    f_exact = bool(np.array_equal(np.asarray(rf.response_ms),
                                  np.asarray(rf1.response_ms)))
    f_blk, f_res, _ = fsim.engine_config("raptor")
    record["queue_faults"] = {
        "vector_jobs": f_jobs * f_trials, "wall_s": f_wall,
        "jobs_per_s": f_tps, "compile_cold_s": f_cold,
        "compile_warm_s": f_warm, "block": f_blk, "resolver": f_res,
        "bitwise_equals_oracle": f_exact,
        "vs_queue_nofault": f_tps / b_tps,
        "mean_ms": rf.summary()["mean"],
        "fail_rate": rf.summary()["fail_rate"],
    }
    _row("sim_queue_faults", f_wall * 1e6 / (f_jobs * f_trials),
         f"faulty={f_tps:.0f}j/s_x{f_tps/b_tps:.2f}_vs_nofault"
         f"_block={f_blk}/{f_res}_bitwise={f_exact}"
         f"_cold={f_cold:.1f}s_warm={f_warm:.2f}s")

    # ---- queue_streaming: open MMPP arrivals, persistent W-state -------
    # The streaming scheduler service (sim/streaming.py): ONE open
    # arrival stream microbatched onto the persistent device-resident
    # free-at vector, host ingest pipelined against device booking.
    # Unlike the batch tiers there is no trial axis to vmap — jobs/s here
    # is SUSTAINED single-stream service throughput under bursty (MMPP)
    # open load, with the latency distribution (p50/p99 sojourn, SLO
    # violations) the service exists to measure.  Bitwise acceptance
    # rides along: the booked stream replayed whole-trace through the
    # block=1 oracle must match exactly (oracle_check).
    from repro.sim.events import MMPPArrivals
    from repro.sim.streaming import oracle_check, run_open_load
    s_sim = QueueFlightSim(keygen_queue(), load="medium", seed=0, **HA)
    st_jobs = max(trials // 2, 1024)
    st_mb = 128

    def st_mmpp():
        return MMPPArrivals(s_sim.rate_hz, burst_factor=5.0,
                            dwell_s=(20.0, 4.0), seed=1)

    t0 = time.time()
    run_open_load(s_sim, jobs=st_mb, microbatch=st_mb, process=st_mmpp(),
                  warmup=False, seed=0)
    st_cold = time.time() - t0
    jax.clear_caches()            # drop in-memory exe; reload from disk
    t0 = time.time()
    run_open_load(s_sim, jobs=st_mb, microbatch=st_mb, process=st_mmpp(),
                  warmup=False, seed=0)
    st_warm = time.time() - t0
    st_rep = None
    for _ in range(reps):
        r = run_open_load(s_sim, jobs=st_jobs, microbatch=st_mb,
                          process=st_mmpp(), warmup=False, seed=0)
        if st_rep is None or r.jobs_per_s > st_rep.jobs_per_s:
            st_rep = r
    st_exact = oracle_check(s_sim, n_steps=4, microbatch=32)["bitwise"]
    st_blk, st_res, _ = s_sim.engine_config("raptor")
    record["queue_streaming"] = {
        "jobs": st_rep.jobs, "microbatch": st_mb,
        "jobs_per_s": st_rep.jobs_per_s, "wall_s": st_rep.wall_s,
        "compile_cold_s": st_cold, "compile_warm_s": st_warm,
        "block": st_blk, "resolver": st_res,
        "arrivals": "mmpp", "offered_rate_hz": st_rep.offered_rate_hz,
        "mean_ms": st_rep.mean_ms, "p50_ms": st_rep.p50_ms,
        "p99_ms": st_rep.p99_ms, "slo_ms": st_rep.slo_ms,
        "slo_violation_frac": st_rep.slo_violation_frac,
        "bitwise_equals_oracle": st_exact,
    }
    _row("sim_queue_streaming", st_rep.wall_s * 1e6 / st_rep.jobs,
         f"sustained={st_rep.jobs_per_s:.0f}j/s_p99={st_rep.p99_ms:.0f}ms"
         f"_slo_viol={st_rep.slo_violation_frac:.3f}"
         f"_block={st_blk}/{st_res}_bitwise={st_exact}"
         f"_cold={st_cold:.1f}s_warm={st_warm:.2f}s")

    # ---- sweep-sharded: the config grid over the device mesh -----------
    # The closed-loop utilisation grid through the SweepPlan driver
    # (sim/sweeps.py), config axis sharded over every (forced-host)
    # device vs pinned to one.  The closed-loop event scans are tiny-op
    # dispatch-bound work XLA cannot intra-op-parallelize, so this is
    # where device sharding pays near-linearly; the open-loop cores
    # already saturate the host on one device, so the sweep_scale grid
    # is checked for sharded == single-device summaries instead (the
    # shard axis is pure batching — results must be bit-identical).
    from repro.sim.vector_queue import rate_sweep
    n_dev = jax.device_count()
    wl_q = keygen_queue()
    utils = [0.1 + 0.75 * i / 11 for i in range(12)]
    rates = [u * HA["num_workers"] / wl_q.work_est_ws for u in utils]
    sh_jobs, sh_trials = max(trials // 16, 256), 16

    def sweep_grid(devices):
        return rate_sweep(wl_q, rates, num_workers=HA["num_workers"],
                          num_azs=HA["num_azs"], jobs=sh_jobs,
                          trials=sh_trials, seed=0, devices=devices)

    one = sweep_grid(1)               # compile outside the timed window
    sharded = sweep_grid(None)
    one_wall = best_of(lambda: sweep_grid(1))
    sh_wall = best_of(lambda: sweep_grid(None))
    grid_jobs = len(rates) * sh_jobs * sh_trials * 2
    from repro.sim.vector import exponential_vector, sweep_pairs
    scale_grid = ([dict(flight=4, num_azs=a) for a in (1, 2, 3, 4, 6, 8)]
                  + [dict(flight=f, num_azs=8) for f in (2, 4, 8, 16)])
    wl_o = exponential_vector(2, 1000.0)
    sc_trials = min(trials, 4000)
    scale_match = (
        sweep_pairs(wl_o, scale_grid, trials=sc_trials, seed=0, devices=1)
        == sweep_pairs(wl_o, scale_grid, trials=sc_trials, seed=0,
                       devices=None))
    record["sweep_sharded"] = {
        "devices": n_dev, "grid_points": len(rates),
        "vector_jobs": grid_jobs,
        "jobs_per_s": grid_jobs / sh_wall,
        "jobs_per_s_1dev": grid_jobs / one_wall,
        "multiplier": one_wall / sh_wall,
        "summaries_match": bool(one == sharded),
        "scale_grid_summaries_match": bool(scale_match),
    }
    _row("sim_sweep_sharded", sh_wall * 1e6 / grid_jobs,
         f"1dev={grid_jobs/one_wall:.0f}j/s_sharded={grid_jobs/sh_wall:.0f}j/s"
         f"_x{one_wall/sh_wall:.2f}_devices={n_dev}"
         f"_match={bool(one == sharded)}_scale_match={bool(scale_match)}"
         f"_target>=2x_on_4dev")

    # ---- the fig6-equivalent load sweep (acceptance: >=50x) ------------
    s_jobs = 0
    s_wall = 0.0
    from repro.sim.experiments import LOW_AVAIL
    for dep in (LOW_AVAIL, HA):
        for load in ("low", "medium", "high"):
            for raptor in (False, True):
                n, s = _scalar_jobs_per_s(
                    keygen_workload, dep, load, max(trials // 8, 256),
                    raptor=raptor)
                s_jobs += n
                s_wall += s
    sw_jobs, sw_trials = max(trials // 4, 512), 48

    def fig6_vector():
        for dep in (LOW_AVAIL, HA):
            load_sweep(keygen_queue(), num_workers=dep["num_workers"],
                       num_azs=dep["num_azs"], jobs=sw_jobs,
                       trials=sw_trials, seed=0)

    fig6_vector()                 # compile outside the timed window
    v_wall = best_of(fig6_vector)
    v_jobs = sw_jobs * sw_trials * 3 * 2 * 2
    record["fig6_sweep"] = {
        "scalar_jobs": s_jobs, "scalar_jobs_per_s": s_jobs / s_wall,
        "vector_jobs": v_jobs, "vector_jobs_per_s": v_jobs / v_wall,
        "speedup": (v_jobs / v_wall) / (s_jobs / s_wall),
    }
    _row("sim_fig6_sweep", v_wall * 1e6 / v_jobs,
         f"scalar={s_jobs/s_wall:.0f}j/s_vector={v_jobs/v_wall:.0f}j/s"
         f"_speedup={record['fig6_sweep']['speedup']:.0f}x_target>=50x")

    path = os.path.join(os.path.dirname(__file__), "..", "BENCH_sim.json")
    with open(os.path.abspath(path), "w") as f:
        json.dump(record, f, indent=2)


def bench_engine_speculation():
    """Live threaded engine: speculative flight on real jitted stages."""
    import jax
    import numpy as np
    from repro.configs import get_config, reduced_config
    from repro.models import init_params
    from repro.serving.engine import ServeConfig, ServingEngine, demo_requests

    cfg = reduced_config(get_config("gemma-2b"))
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, params, ServeConfig(
        max_len=24, decode_steps=4, flight_size=2, mean_jitter_s=0.05))
    batch = demo_requests(cfg, batch=2, prompt_len=8)
    eng.generate(batch)                       # warm up jits
    stock, raptor = [], []
    rng = np.random.default_rng(0)
    t0 = time.time()
    for i in range(8):
        r1 = eng.generate(batch)
        stock.append(r1.latency_s + rng.exponential(0.05, 2).sum())
        r2 = eng.generate_flight(batch)
        raptor.append(r2.latency_s)
    us = (time.time() - t0) * 1e6 / 16
    _row("engine_speculation", us,
         f"stock_mean={np.mean(stock)*1e3:.0f}ms"
         f"_flight_mean={np.mean(raptor)*1e3:.0f}ms_exact_tokens=True")


def bench_kernels():
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.kernels.flash_attention.kernel import flash_attention
    from repro.kernels.flash_attention.ref import attention_ref
    q = jax.random.normal(jax.random.PRNGKey(0), (1, 4, 256, 64))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 2, 256, 64))
    v = jax.random.normal(jax.random.PRNGKey(2), (1, 2, 256, 64))
    t0 = time.time()
    out = flash_attention(q, k, v, block_q=128, block_k=128, interpret=True)
    us = (time.time() - t0) * 1e6
    err = float(jnp.max(jnp.abs(out - attention_ref(q, k, v))))
    _row("kernel_flash_interpret", us, f"max_err={err:.2e}")


def bench_roofline():
    path = os.path.join(os.path.dirname(__file__), "..", "dryrun_results.json")
    path = os.path.abspath(path)
    if not os.path.exists(path):
        _row("roofline", 0.0, "dryrun_results.json_missing_run_dryrun_first")
        return
    sys.path.insert(0, os.path.dirname(__file__))
    from roofline import table
    rows = table(path)
    for r in rows:
        _row(f"roofline/{r['arch']}/{r['shape']}", 0.0,
             f"compute={r['t_compute_s']:.4f}s_memory={r['t_memory_s']:.4f}s"
             f"_coll={r['t_collective_s']:.4f}s_dom={r['dominant']}"
             f"_useful={r['useful_ratio']:.2f}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("targets", nargs="*",
                    help="subset of benches to run (e.g. sim-vector); "
                         "empty = the full paper sweep")
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--skip-engine", action="store_true")
    ap.add_argument("--trials", type=int, default=10000,
                    help="vector-sim trial count for sim-vector")
    args, _ = ap.parse_known_args()
    dur = 200.0 if args.fast else 600.0
    print("name,us_per_call,derived")
    # single registry: insertion order is the full-sweep order; targets in
    # JAX_TIER need jax and are dropped by --skip-engine so the scalar
    # numpy-only sweep keeps working on a bare interpreter
    named = {
        "table6": bench_table6_overhead,
        "table7": lambda: bench_table7_keygen(dur),
        "fig6": lambda: bench_fig6_scale(dur),
        "fig7": lambda: bench_fig7_workloads(dur),
        "fig8": lambda: bench_fig8_reliability(min(dur, 400.0)),
        "sim-vector": lambda: bench_sim_vector(args.trials),
        "engine": bench_engine_speculation,
        "kernels": bench_kernels,
        "roofline": bench_roofline,
    }
    jax_tier = {"sim-vector", "engine", "kernels"}
    targets = args.targets or [t for t in named
                               if not (args.skip_engine and t in jax_tier)]
    # fig6/fig7 default to the vector engine (with a scalar fallback on
    # numpy-only interpreters), so they benefit from the cache too — but
    # must not make a bare interpreter crash here
    if any(t in jax_tier or t in ("fig6", "fig7") for t in targets):
        try:
            # multi-controller sweeps on CPU-only hosts: split the host
            # into 4 devices BEFORE the backend initializes (no-op when
            # XLA_FLAGS already forces a count, e.g. in CI)
            from repro.sim.sweeps import force_host_devices
            force_host_devices(4)
            enable_compile_cache()
        except ImportError:
            pass                  # numpy-only: scalar fallbacks still run
    for t in targets:
        if t not in named:
            raise SystemExit(f"unknown bench target {t!r}; "
                             f"choose from {sorted(named)}")
        named[t]()


if __name__ == "__main__":
    main()
