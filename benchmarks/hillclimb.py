import os
if "--xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    # append, never overwrite: a user-supplied XLA_FLAGS (tuning flags,
    # dump dirs) must survive; an explicit device count wins outright
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count"
                                 "=512").strip()

"""Perf-iteration driver (EXPERIMENTS.md §Perf): lower one (arch, shape)
cell under a named sharding/step variant and report the three roofline
terms, so hypothesis -> change -> measure cycles take one command.

    PYTHONPATH=src python benchmarks/hillclimb.py --arch gemma-2b \
        --shape train_4k --variant sp

Variants:
    baseline      the sweep configuration
    sp            sequence-parallel residual stream (heads-fallback archs)
    moe_align     tokens pre-sharded to the EP layout before shard_map
    grads_bf16    bf16 gradient all-reduce (halves DP collective bytes)
    no_zero3      replicate params over the data axis (serving: kills the
                  per-step weight all-gather that ZeRO-3 storage implies)
    sp+moe_align  combinations via '+'
"""
import argparse
import json
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.dirname(__file__))

import jax  # noqa: E402

from repro.configs import get_config, shape_by_name  # noqa: E402
from repro.distributed.collectives import compress_grads  # noqa: E402
from repro.distributed.sharding import Plan  # noqa: E402
from repro.launch import specs as S  # noqa: E402
from repro.launch.dryrun import analyze, collective_bytes  # noqa: E402
from repro.launch.mesh import batch_axes, make_production_mesh  # noqa: E402
from repro.models.moe import EPSpec  # noqa: E402
from repro.serving.step import cache_shape, make_decode_step, make_prefill_step  # noqa: E402
from repro.training.optimizer import OptConfig  # noqa: E402
from repro.training.step import StepOptions, make_train_step, train_state_shape  # noqa: E402
from roofline import analyze_record  # noqa: E402


def lower_variant(arch: str, shape_name: str, variant: str, multi_pod=False):
    import dataclasses
    cfg = get_config(arch)
    shape = shape_by_name(shape_name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    opts = set(variant.split("+"))
    if "pad_heads" in opts:
        cfg = dataclasses.replace(cfg, pad_heads=-(-cfg.num_heads // 16) * 16)
    sp = True if "sp" in opts else (False if "no_sp" in opts else None)
    plan = Plan(mesh, cfg,
                seq_parallel=sp,
                moe_token_align="moe_align" in opts,
                zero3="no_zero3" not in opts)
    ep = EPSpec(mesh, batch_axes(mesh)) if cfg.moe is not None else None
    grad_transform = compress_grads("bf16") if "grads_bf16" in opts else None
    step_options = StepOptions(
        remat_policy="dots" if "remat_dots" in opts else None)

    with mesh:
        if shape.kind == "train":
            oc = OptConfig(state_dtype=cfg.optimizer_state_dtype)
            step = make_train_step(cfg, oc, constrain=plan.constrain, ep=ep,
                                   grad_transform=grad_transform,
                                   options=step_options)
            state_shape = train_state_shape(cfg, oc)
            state_sh = {
                "params": plan.param_shardings(state_shape["params"]),
                "opt": {
                    "mu": plan.param_shardings(state_shape["opt"]["mu"]),
                    "nu": plan.param_shardings(state_shape["opt"]["nu"]),
                    "step": jax.sharding.NamedSharding(
                        mesh, jax.sharding.PartitionSpec()),
                },
            }
            batch_shape = S.train_batch_specs(cfg, shape)
            fn = jax.jit(step, in_shardings=(state_sh,
                                             plan.batch_shardings(batch_shape)),
                         donate_argnums=(0,))
            lowered = fn.lower(state_shape, batch_shape)
        elif shape.kind == "prefill":
            from repro.models import init_params
            params_shape = jax.eval_shape(
                lambda: init_params(cfg, jax.random.key(0)))
            step = make_prefill_step(cfg, max_len=shape.seq_len,
                                     constrain=plan.constrain, ep=ep)
            batch_shape = S.prefill_batch_specs(cfg, shape)
            lowered = jax.jit(step, in_shardings=(
                plan.param_shardings(params_shape),
                plan.batch_shardings(batch_shape))
            ).lower(params_shape, batch_shape)
        else:
            from repro.models import init_params
            params_shape = jax.eval_shape(
                lambda: init_params(cfg, jax.random.key(0)))
            step = make_decode_step(cfg, constrain=plan.constrain, ep=ep)
            cache = cache_shape(cfg, shape.global_batch, shape.seq_len,
                                enc_len=S.enc_len_for(cfg, shape))
            tok = S.decode_token_specs(cfg, shape)
            lowered = jax.jit(step, in_shardings=(
                plan.param_shardings(params_shape),
                plan.cache_shardings(cache),
                plan.batch_shardings(tok)), donate_argnums=(1,)
            ).lower(params_shape, cache, tok)
        rec = {"arch": arch, "shape": shape_name,
               "mesh": "2x16x16" if multi_pod else "16x16", "ok": True}
        rec.update(analyze(lowered))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--json", default=None)
    args = ap.parse_args()
    rec = lower_variant(args.arch, args.shape, args.variant, args.multi_pod)
    roof = analyze_record(rec)
    print(f"== {args.arch} x {args.shape} [{args.variant}] ==")
    print(f"compile_s={rec['compile_s']} n_collectives={rec['n_collectives']}"
          f" peak={rec['peak_bytes_per_device']/2**30:.1f}GiB(cpu-f32)")
    for k in ("t_compute_s", "t_memory_s", "t_collective_s"):
        print(f"{k}: {roof[k]:.5f}")
    print(f"dominant={roof['dominant']} useful={roof['useful_ratio']:.2f} "
          f"roofline_fraction={roof['roofline_fraction']*100:.1f}%")
    if args.json:
        with open(args.json, "a") as f:
            f.write(json.dumps({"variant": args.variant, **rec,
                                "roof": roof}) + "\n")


if __name__ == "__main__":
    main()
