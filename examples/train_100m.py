"""End-to-end training driver: a ~100M-parameter gemma-family model trained
for a few hundred steps on the synthetic pipeline with checkpoint/resume and
Raptor redundant-DP fault tolerance (a simulated pod failure mid-run).

CPU note: full 100M x hundreds of steps takes ~an hour on this 1-core
container; --fast trains a 25M twin for 150 steps (same code path).  On a
TPU mesh the same script runs the full config unchanged.

    PYTHONPATH=src python examples/train_100m.py --fast
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import io as ckpt_io
from repro.configs import get_config
from repro.configs.base import ModelConfig, ShapeConfig
from repro.data.synthetic import make_batch
from repro.training.optimizer import OptConfig
from repro.training.raptor_dp import signals_to_weights
from repro.training.step import (StepOptions, init_train_state,
                                 make_train_step)


def model_100m(fast: bool) -> ModelConfig:
    base = get_config("gemma-2b")
    if fast:
        return dataclasses.replace(
            base, name="gemma-25m", num_layers=4, d_model=320, num_heads=4,
            num_kv_heads=1, head_dim=64, d_ff=1280, vocab_size=32000,
            window_size=256, dtype="float32")
    return dataclasses.replace(
        base, name="gemma-100m", num_layers=8, d_model=640, num_heads=8,
        num_kv_heads=1, head_dim=64, d_ff=2560, vocab_size=32000,
        window_size=256, dtype="float32")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--steps", type=int, default=150)
    ap.add_argument("--ckpt", default="/tmp/repro_100m_ckpt")
    args = ap.parse_args(argv)

    cfg = model_100m(args.fast)
    n_params = cfg.param_counts()["total"]
    print(f"{cfg.name}: ~{n_params/1e6:.0f}M params, {args.steps} steps")
    shape = ShapeConfig("train", 128, 4, "train")
    oc = OptConfig(lr=1e-3, warmup_steps=20, total_steps=args.steps,
                   weight_decay=0.01)
    step = jax.jit(make_train_step(cfg, oc, options=StepOptions(remat=False)))
    state = init_train_state(cfg, oc, jax.random.PRNGKey(0))

    start = 0
    try:
        state, start = ckpt_io.restore(args.ckpt, state)
        start += 1
        print(f"resumed at step {start}")
    except FileNotFoundError:
        pass

    t0, tokens = time.time(), 0
    for i in range(start, args.steps):
        batch = {k: jnp.asarray(v)
                 for k, v in make_batch(cfg, shape, i).items()}
        health = np.ones(2)
        if i == args.steps // 2:
            health[1] = 0.0      # pod loss mid-run; flight degrades, no stop
        batch["loss_weight"] = jnp.asarray(
            signals_to_weights(shape.global_batch, 2, health=health))
        state, m = step(state, batch)
        tokens += shape.global_batch * shape.seq_len
        if i % 25 == 0 or i == args.steps - 1:
            dt = time.time() - t0
            print(f"step {i}: loss={float(m['loss']):.3f} "
                  f"({tokens/max(dt,1e-9):.0f} tok/s)")
        if i % 50 == 0:
            ckpt_io.save(args.ckpt, i, state)
    ckpt_io.save(args.ckpt, args.steps - 1, state)
    print("done; checkpoint committed")


if __name__ == "__main__":
    main()
