"""End-to-end serving driver (the paper's kind): batched requests served by
a real model, with and without Raptor speculative flights, under injected
host latency variance.  Reports the latency distribution improvement — the
live-engine analogue of Table 7.

    PYTHONPATH=src python examples/serve_flight.py [--requests 20]
"""
import argparse

import jax
import numpy as np

from repro.configs import get_config, reduced_config
from repro.core.analytics import summarize
from repro.models import init_params
from repro.serving.engine import ServeConfig, ServingEngine, demo_requests


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="phi3-mini-3.8b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--flight", type=int, default=2)
    ap.add_argument("--jitter-ms", type=float, default=60.0)
    args = ap.parse_args(argv)

    cfg = reduced_config(get_config(args.arch))
    params = init_params(cfg, jax.random.PRNGKey(0))
    sc = ServeConfig(max_len=40, decode_steps=8, flight_size=args.flight,
                     mean_jitter_s=args.jitter_ms / 1e3)
    eng = ServingEngine(cfg, params, sc)

    stock, raptor = [], []
    for i in range(args.requests):
        batch = demo_requests(cfg, batch=4, prompt_len=16, seed=i)
        # stock path still pays one host's jitter draw
        jit = float(np.random.default_rng(i).exponential(sc.mean_jitter_s, 2).sum())
        r1 = eng.generate(batch)
        stock.append(r1.latency_s + jit)
        r2 = eng.generate_flight(batch)
        raptor.append(r2.latency_s)
        np.testing.assert_array_equal(r1.tokens, r2.tokens)  # exactness

    s, r = summarize(stock), summarize(raptor)
    print(f"stock : mean={s['mean']*1e3:.0f}ms p90={s['p90']*1e3:.0f}ms")
    print(f"raptor: mean={r['mean']*1e3:.0f}ms p90={r['p90']*1e3:.0f}ms "
          f"(flight={args.flight}, exact same tokens)")
    print(f"mean ratio: {r['mean']/s['mean']:.3f}")


if __name__ == "__main__":
    main()
