"""Quickstart: build an architecture, take a train step, serve a batch, and
run one Raptor flight — the whole public API in ~60 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced_config
from repro.configs.base import ShapeConfig
from repro.core.manifest import ActionManifest, FunctionSpec
from repro.core.scheduler import Flight
from repro.data.synthetic import make_batch
from repro.models import init_params
from repro.serving.engine import ServeConfig, ServingEngine, demo_requests
from repro.training.optimizer import OptConfig
from repro.training.step import StepOptions, init_train_state, make_train_step


def main():
    # -- pick an architecture (any of the ten assigned ids) -------------
    cfg = reduced_config(get_config("gemma2-9b"))   # CPU-sized twin
    print(f"arch: {cfg.name} ({cfg.num_layers}L d={cfg.d_model})")

    # -- one training step ----------------------------------------------
    oc = OptConfig()
    step = jax.jit(make_train_step(cfg, oc, options=StepOptions(remat=False)))
    state = init_train_state(cfg, oc, jax.random.PRNGKey(0))
    batch = {k: jnp.asarray(v) for k, v in
             make_batch(cfg, ShapeConfig("s", 32, 4, "train"), 0).items()}
    state, metrics = step(state, batch)
    print(f"train step: loss={float(metrics['loss']):.3f}")

    # -- batched serving --------------------------------------------------
    eng = ServingEngine(cfg, state["params"],
                        ServeConfig(max_len=24, decode_steps=6))
    res = eng.generate(demo_requests(cfg, batch=2, prompt_len=8))
    print(f"served 2 requests, 6 tokens each in {res.latency_s*1e3:.0f} ms: "
          f"{res.tokens.tolist()}")

    # -- a Raptor flight over a user DAG ----------------------------------
    def work(ctx):
        ctx.sleep(0.01)
        return f"{ctx.task_name}@{ctx.follower_index}"

    man = ActionManifest((
        FunctionSpec("extract", work),
        FunctionSpec("transform", work, dependencies=("extract",)),
        FunctionSpec("load", work, dependencies=("transform",)),
    ), concurrency=2, name="etl")
    rep = Flight(man).run()
    print(f"flight ok={rep.ok} outputs={rep.outputs} "
          f"busy={rep.total_busy*1e3:.0f}ms over {len(rep.executors)} members")


if __name__ == "__main__":
    main()
