"""Scale sweep: watch the paper's exponential-RV prediction emerge as the
deployment spreads across availability zones.

The paper's headline claim is that Raptor's mean-delay win is predicted by
mutually independent exponential random variables — but only once the
framework is horizontally scaled across AZs.  At 1 AZ every flight member
shares the AZ's entropy-pool state (rho=0.95 of the service time), so
racing replicas buys nothing; as AZs are added the members decorrelate and
the measured ratio converges to the order-statistics prediction.

Runs in seconds: every configuration is a vectorized on-device Monte-Carlo
batch (sim/vector.py), not the scalar event loop — and the closed-loop
load curve at the bottom runs through the device-sharded sweep driver
(sim/sweeps.py): on a CPU-only host the process is split into 4 forced
host devices and the utilisation grid shards over them (bit-identical to
the single-device run, just faster).

    PYTHONPATH=src python examples/scale_sweep.py
"""
from repro.core.analytics import raptor_speedup_prediction
from repro.sim.sweeps import force_host_devices
from repro.sim.vector import (VectorFlightSim, exponential_vector,
                              keygen_vector)

TRIALS = 40_000
FLIGHT = 4
# every sim/sweep below takes this explicit seed, so a rerun reproduces
# the printed table bit-for-bit (the repo-wide seed convention: never rely
# on a default seed — see tests/test_queue_properties.py)
SEED = 0


def main():
    # split a CPU-only host into 4 devices for the sharded sweep path;
    # must run before the first jax dispatch (no-op afterwards / on
    # multi-chip hosts — returns the live device count either way)
    n_dev = force_host_devices(4)
    theory = raptor_speedup_prediction(num_tasks=2, flight=FLIGHT)
    print(f"sweep device mesh: {n_dev} device(s)")
    print(f"exp(1) tasks, flight of {FLIGHT}, rho=0.95, {TRIALS} trials/point")
    print(f"independent-exponential prediction: ratio = {theory:.3f}\n")
    print(f"{'AZs':>4} {'stock mean':>11} {'raptor mean':>12} "
          f"{'ratio':>7} {'gap to theory':>14}")
    for num_azs in (1, 2, 3, 4, 6, 8):
        sim = VectorFlightSim(exponential_vector(2, 1000.0),
                              num_azs=num_azs, flight=FLIGHT, rho=0.95,
                              seed=SEED)
        pair = sim.run_pair(TRIALS)
        ratio = pair["mean_ratio"]
        print(f"{num_azs:>4} {pair['stock']['mean']:>9.0f}ms "
              f"{pair['raptor']['mean']:>10.0f}ms {ratio:>7.3f} "
              f"{ratio - theory:>+13.3f}")

    print("\npaper deployment (ssh-keygen, flight of 2, 3 AZs):")
    pair = VectorFlightSim(keygen_vector(), num_azs=3, flight=2,
                           seed=SEED).run_pair(TRIALS)
    print(f"  measured ratio {pair['mean_ratio']:.3f}  "
          f"(paper 0.647, theory {raptor_speedup_prediction(2, 2):.3f})")

    load_curve()


def load_curve():
    """Closed-loop load sweep (fig6's other axis): the ratio vs utilisation.

    Arrival rate is a traced knob of the queue engine, so the whole curve
    per deployment is one vmapped call — and it shows the regime the
    open-loop batch cannot: at the 1-AZ/5-worker deployment a flight of 2
    DOUBLES per-job worker demand, so Raptor actively hurts once the queue
    bites (the paper's Kafka-queue-domination note, §4.2.1), while the HA
    deployment keeps most of its win to moderate load.
    """
    from repro.sim.experiments import load_sweep_util
    print("\nclosed-loop load sweep (ssh-keygen, ratio vs utilisation):")
    # 0.9: the new deep-queueing point the task-FCFS stock engine made
    # faithful (the 1-AZ/5-worker deployment is flight-saturated there;
    # see the growth-rate note on load_sweep_util)
    res = load_sweep_util(utils=(0.15, 0.3, 0.45, 0.6, 0.75, 0.9),
                          seed=SEED)
    rows = {}
    for key, pair in res.items():
        dep, util = key.rsplit("/util", 1)
        rows.setdefault(float(util), {})[dep] = pair["mean_ratio"]
    print(f"{'util':>6} {'one_az_5w':>10} {'three_az_15w':>13}")
    for util in sorted(rows):
        r = rows[util]
        print(f"{util:>6.2f} {r['one_az_5w']:>10.3f} "
              f"{r['three_az_15w']:>13.3f}")


if __name__ == "__main__":
    main()
