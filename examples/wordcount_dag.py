"""The paper's word-count map-reduce workflow on the REAL Raptor engine:
split -> 4x map -> reduce over actual text, executed as a flight with state
sharing carrying the data between stages (no storage round-trips).

    PYTHONPATH=src python examples/wordcount_dag.py
"""
import collections
import time

from repro.core.manifest import ActionManifest, FunctionSpec
from repro.core.scheduler import Flight

TEXT = ("the quick brown fox jumps over the lazy dog " * 200 +
        "raptor schedules serverless functions with speculation " * 150)


def split(ctx):
    words = TEXT.split()
    n = len(words) // 4
    return [words[i * n:(i + 1) * n if i < 3 else None] for i in range(4)]


def make_map(i):
    def map_fn(ctx):
        shard = ctx.inputs["split"][i]
        ctx.checkpoint()
        return dict(collections.Counter(shard))
    return map_fn


def reduce_fn(ctx):
    total = collections.Counter()
    for i in range(4):
        total.update(ctx.inputs[f"map{i}"])
    return dict(total)


def main():
    fns = [FunctionSpec("split", split)]
    fns += [FunctionSpec(f"map{i}", make_map(i), ("split",)) for i in range(4)]
    fns.append(FunctionSpec(
        "reduce", reduce_fn, tuple(f"map{i}" for i in range(4))))
    man = ActionManifest(tuple(fns), concurrency=2, name="wordcount")

    t0 = time.monotonic()
    rep = Flight(man).run()
    dt = (time.monotonic() - t0) * 1e3
    top = sorted(rep.outputs["reduce"].items(), key=lambda kv: -kv[1])[:3]
    print(f"ok={rep.ok} in {dt:.1f} ms, flight of {len(rep.executors)}")
    print(f"top words: {top}")
    skipped = sum(len(e.skipped) for e in rep.executors)
    print(f"speculation stats: skipped={skipped} "
          f"duplicates={rep.duplicates} busy={rep.total_busy*1e3:.1f} ms")
    assert rep.outputs["reduce"]["the"] == 400  # 2 per sentence x 200 reps


if __name__ == "__main__":
    main()
