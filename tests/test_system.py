"""End-to-end behaviour tests for the full system: train -> checkpoint ->
crash -> resume -> serve, with Raptor fault tolerance in the loop."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import io as ckpt_io
from repro.configs import get_config, reduced_config
from repro.configs.base import ShapeConfig
from repro.data.synthetic import make_batch
from repro.serving.engine import ServeConfig, ServingEngine, demo_requests
from repro.training.optimizer import OptConfig
from repro.training.raptor_dp import signals_to_weights
from repro.training.step import (StepOptions, init_train_state,
                                 make_train_step)


def test_train_crash_resume_serve(tmp_path):
    cfg = reduced_config(get_config("phi3-mini-3.8b"))
    shape = ShapeConfig("sys", 32, 4, "train")
    oc = OptConfig(lr=1e-3, warmup_steps=2, total_steps=20)
    step = jax.jit(make_train_step(cfg, oc, options=StepOptions(remat=False)))

    # phase 1: train 6 steps with a mid-run pod failure, checkpoint each
    state = init_train_state(cfg, oc, jax.random.PRNGKey(0))
    for i in range(6):
        batch = {k: jnp.asarray(v)
                 for k, v in make_batch(cfg, shape, i).items()}
        health = np.ones(2)
        if i == 3:
            health[1] = 0.0          # flight member dies; step proceeds
        batch["loss_weight"] = jnp.asarray(
            signals_to_weights(4, 2, health=health))
        state, m = step(state, batch)
        ckpt_io.save(str(tmp_path), i, state)
    loss_before = float(m["loss"])

    # phase 2: "crash" — rebuild from checkpoint, continue deterministically
    state2 = init_train_state(cfg, oc, jax.random.PRNGKey(0))
    state2, last = ckpt_io.restore(str(tmp_path), state2)
    assert last == 5
    for i in range(last + 1, last + 4):
        batch = {k: jnp.asarray(v)
                 for k, v in make_batch(cfg, shape, i).items()}
        state2, m2 = step(state2, batch)
    assert np.isfinite(float(m2["loss"]))
    assert int(state2["opt"]["step"]) == 9

    # phase 3: serve the trained weights, stock vs flight must agree
    eng = ServingEngine(cfg, state2["params"],
                        ServeConfig(max_len=24, decode_steps=4,
                                    flight_size=2, mean_jitter_s=0.005))
    req = demo_requests(cfg, batch=2, prompt_len=8)
    r_stock = eng.generate(req)
    r_flight = eng.generate_flight(req)
    np.testing.assert_array_equal(r_stock.tokens, r_flight.tokens)


def test_all_families_one_train_step():
    """One real (non-lowered) step for one arch of each family."""
    for arch in ("gemma2-9b", "granite-moe-3b-a800m", "mamba2-1.3b",
                 "zamba2-1.2b", "seamless-m4t-medium", "qwen2-vl-2b"):
        cfg = reduced_config(get_config(arch))
        shape = ShapeConfig("sys", 16, 2, "train")
        oc = OptConfig(total_steps=5)
        step = jax.jit(make_train_step(cfg, oc,
                                       options=StepOptions(remat=True)))
        state = init_train_state(cfg, oc, jax.random.PRNGKey(0))
        batch = {k: jnp.asarray(v)
                 for k, v in make_batch(cfg, shape, 0).items()}
        state, m = step(state, batch)
        assert np.isfinite(float(m["loss"])), arch
