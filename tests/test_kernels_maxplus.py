"""Max-plus summary-scan Pallas kernel vs the associative_scan oracle.

Runs in interpret mode so the kernel tier is exercised on CPU-only CI
(ci.yml runs this file explicitly).  The doubling scan inside the kernel
brackets the operator tape differently from both the oracle's
``lax.associative_scan`` tree and a sequential fold, so bitwise parity
here is exactly the associativity the algebra tests promise — now checked
through the real Pallas lowering, including the -inf identity padding the
shift steps introduce.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    import hypothesis
    import hypothesis.strategies as st
except ModuleNotFoundError:  # bare env: property tests skip, rest still run
    from _hypothesis_compat import hypothesis, st

from repro.kernels.maxplus_scan.ops import maxplus_entries
from repro.kernels.maxplus_scan.ref import maxplus_scan_ref


def make(seed, T, nb, W, diag_free=True, p_ninf=0.25):
    """Random factored operator tapes.  Integer-valued float32 keeps the
    d1+d2 / b1+d2 composes exact so every comparison can be bitwise;
    ``diag_free=False`` emits the production shape (diag identically 0,
    where compose degenerates to elementwise max)."""
    rng = np.random.default_rng(seed)
    if diag_free:
        diag = rng.integers(-20, 20, (T, nb, W)).astype(np.float32)
    else:
        diag = np.zeros((T, nb, W), np.float32)
    off = rng.integers(0, 1000, (T, nb, W)).astype(np.float32)
    off = np.where(rng.uniform(size=off.shape) < p_ninf, -np.inf, off)
    wf0 = rng.integers(0, 500, (T, W)).astype(np.float32)
    return jnp.asarray(diag), jnp.asarray(off), jnp.asarray(wf0)


def seq_fold(diag, off, wf0):
    """Sequential-fold oracle, independent of any scan machinery."""
    diag, off, wf0 = (np.asarray(x) for x in (diag, off, wf0))
    T, nb, W = diag.shape
    entries = np.empty((T, nb, W), np.float32)
    wf = wf0.copy()
    for k in range(nb):
        entries[:, k] = wf
        wf = np.maximum(wf + diag[:, k], off[:, k])
    return entries, wf


CASES = [
    # (T, nb, W) — nb spans 1, powers of two, and ragged non-powers
    # (the doubling sweep's shift padding only matters off-power)
    (2, 1, 15),
    (2, 8, 15),
    (3, 5, 15),       # non-power nb
    (4, 13, 7),       # non-power nb, odd W
    (1, 32, 1),       # single worker
    (2, 48, 31),
]


@pytest.mark.parametrize("T,nb,W", CASES)
@pytest.mark.parametrize("diag_free", [True, False])
def test_kernel_matches_ref(T, nb, W, diag_free):
    diag, off, wf0 = make(0, T, nb, W, diag_free=diag_free)
    ent, wf = maxplus_entries(diag, off, wf0, interpret=True)
    rent, rwf = maxplus_scan_ref(diag, off, wf0)
    np.testing.assert_array_equal(np.asarray(ent), np.asarray(rent))
    np.testing.assert_array_equal(np.asarray(wf), np.asarray(rwf))
    # and both agree with a plain sequential fold
    sent, swf = seq_fold(diag, off, wf0)
    np.testing.assert_array_equal(np.asarray(ent), sent)
    np.testing.assert_array_equal(np.asarray(wf), swf)


def test_all_ninf_offsets_pass_through():
    """A tape of pure-diagonal operators (b = -inf everywhere, the
    identity's offset) must shift wf0 and book nothing."""
    T, nb, W = 2, 6, 8
    diag, _, wf0 = make(1, T, nb, W)
    off = jnp.full((T, nb, W), -jnp.inf, jnp.float32)
    ent, wf = maxplus_entries(diag, off, wf0, interpret=True)
    expect = np.asarray(wf0)[:, None] + np.cumsum(np.asarray(diag), axis=1)
    np.testing.assert_array_equal(np.asarray(ent[:, 0]), np.asarray(wf0))
    np.testing.assert_array_equal(np.asarray(ent[:, 1:]), expect[:, :-1])
    np.testing.assert_array_equal(np.asarray(wf), expect[:, -1])


def test_entry_rows_are_exclusive():
    """Row k must NOT include block k's own operator: perturbing block k
    changes rows > k and wf_out but leaves rows <= k untouched."""
    diag, off, wf0 = make(2, 1, 9, 5, diag_free=False)
    ent1, _ = maxplus_entries(diag, off, wf0, interpret=True)
    off2 = off.at[:, 4].set(2000.0)
    ent2, wf2 = maxplus_entries(diag, off2, wf0, interpret=True)
    np.testing.assert_array_equal(
        np.asarray(ent1[:, :5]), np.asarray(ent2[:, :5]))
    assert np.all(np.asarray(ent2[:, 5:]) >= 2000.0)
    assert np.all(np.asarray(wf2) >= 2000.0)


def test_engine_pallas_summary_matches_xla():
    """The in-engine route: QueueFlightSim(scan="logdepth",
    summary_backend="pallas") must replay bit-for-bit like the XLA
    associative_scan — and both like the sequential chain."""
    from repro.sim.vector_queue import QueueFlightSim, wordcount_queue
    kw = dict(num_workers=15, num_azs=3, load="high", seed=0,
              block=16, resolver="unrolled")
    o = QueueFlightSim(wordcount_queue(), **kw)
    a = QueueFlightSim(wordcount_queue(), scan="logdepth", **kw)
    b = QueueFlightSim(wordcount_queue(), scan="logdepth",
                       summary_backend="pallas", **kw)
    ro, ra, rb = (s.run(96, 2, raptor=True).response_ms for s in (o, a, b))
    np.testing.assert_array_equal(np.asarray(ra), np.asarray(rb))
    np.testing.assert_array_equal(np.asarray(ro), np.asarray(rb))
    ta, tb = (s.trace_run(64, 2, raptor=False) for s in (a, b))
    for k in ("ready", "start", "fin", "worker"):
        np.testing.assert_array_equal(ta[k], tb[k])


@hypothesis.given(seed=st.integers(0, 1000), nb=st.integers(1, 24),
                  W=st.sampled_from([1, 7, 15]),
                  diag_free=st.booleans())
@hypothesis.settings(max_examples=10, deadline=None)
def test_kernel_property(seed, nb, W, diag_free):
    diag, off, wf0 = make(seed, 2, nb, W, diag_free=diag_free)
    ent, wf = maxplus_entries(diag, off, wf0, interpret=True)
    rent, rwf = maxplus_scan_ref(diag, off, wf0)
    np.testing.assert_array_equal(np.asarray(ent), np.asarray(rent))
    np.testing.assert_array_equal(np.asarray(wf), np.asarray(rwf))
