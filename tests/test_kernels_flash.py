"""Flash-attention kernel vs pure-jnp oracle: shape/dtype sweep + hypothesis
(validated in interpret mode; TPU is the deploy target)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    import hypothesis
    import hypothesis.strategies as st
except ModuleNotFoundError:  # bare env: property tests skip, rest still run
    from _hypothesis_compat import hypothesis, st

from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ref import attention_ref


def rand_qkv(key, b, hq, hkv, sq, sk, d, dtype):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, hq, sq, d), dtype)
    k = jax.random.normal(ks[1], (b, hkv, sk, d), dtype)
    v = jax.random.normal(ks[2], (b, hkv, sk, d), dtype)
    return q, k, v


CASES = [
    # b, hq, hkv, s, d, causal, window, cap
    (1, 1, 1, 128, 64, True, 0, 0.0),
    (2, 4, 2, 256, 64, True, 0, 0.0),          # GQA
    (1, 8, 1, 128, 128, True, 0, 0.0),         # MQA
    (1, 2, 2, 256, 64, True, 128, 0.0),        # sliding window
    (1, 2, 1, 256, 64, True, 0, 50.0),         # gemma softcap
    (1, 2, 2, 192, 64, True, 0, 0.0),          # ragged seq (mask tail)
    (2, 2, 2, 128, 64, False, 0, 0.0),         # bidirectional (encoder)
]


@pytest.mark.parametrize("b,hq,hkv,s,d,causal,window,cap", CASES)
def test_flash_matches_ref(b, hq, hkv, s, d, causal, window, cap):
    q, k, v = rand_qkv(jax.random.PRNGKey(0), b, hq, hkv, s, s, d, jnp.float32)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          logit_cap=cap, block_q=64, block_k=64,
                          interpret=True)
    ref = attention_ref(q, k, v, causal=causal, window=window, logit_cap=cap)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("dtype,atol", [(jnp.float32, 2e-5), (jnp.bfloat16, 2e-2)])
def test_flash_dtypes(dtype, atol):
    q, k, v = rand_qkv(jax.random.PRNGKey(1), 1, 4, 2, 128, 128, 64, dtype)
    out = flash_attention(q, k, v, block_q=64, block_k=64, interpret=True)
    ref = attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=atol, rtol=atol)


@hypothesis.given(
    b=st.integers(1, 2),
    hkv=st.sampled_from([1, 2]),
    rep=st.sampled_from([1, 2, 4]),
    sq_blocks=st.integers(1, 3),
    d=st.sampled_from([32, 64]),
    window=st.sampled_from([0, 64]),
    seed=st.integers(0, 2**16),
)
@hypothesis.settings(max_examples=12, deadline=None)
def test_flash_property(b, hkv, rep, sq_blocks, d, window, seed):
    s = 64 * sq_blocks
    q, k, v = rand_qkv(jax.random.PRNGKey(seed), b, hkv * rep, hkv, s, s, d,
                       jnp.float32)
    out = flash_attention(q, k, v, window=window, block_q=64, block_k=64,
                          interpret=True)
    ref = attention_ref(q, k, v, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=3e-5, rtol=3e-5)
