"""Workflow-manifest compiler: spec combinators, one IR, every engine.

Three tiers:

* **compiler units + golden IR snapshots** — the combinators compile
  wordcount/thumbnail to literal-tuple IR identical to the hand-rolled
  encodings they replaced (representation identity), cycles die at
  construction naming the cycle, nested conditionals are rejected;
* **replay identity** — a pure-numpy reference replay of the flight
  race (same event order, same float32 arithmetic) pins
  ``dag_flight_trial`` bitwise on random compiled DAGs, including
  ``fail_prob > 0`` and conditional mask-select branches.  When
  ``hypothesis`` is installed the same checker runs under ``@given``;
  the seeded sweep below runs regardless;
* **engine agreement** — the workload-bank graphs (ETL with the
  poison-job conditional, ranked map-reduce with a barrier) replay
  through scalar, vector, and streaming engines and agree.

Seed convention: every test draws from explicit integer seeds.
"""
import dataclasses

import numpy as np
import pytest

from repro.core.dag import kahn_order, validate_acyclic
from repro.core.manifest import ActionManifest, FunctionSpec
from repro.core.workflow import (WorkflowGraph, barrier, branch, chain,
                                 compile_spec, conditional, fanout, task)

try:
    import hypothesis
    import hypothesis.strategies as st
except ModuleNotFoundError:
    from _hypothesis_compat import hypothesis, st


# ------------------------------------------------------------------
# cycle detection (satellite): construction-time, naming the cycle
# ------------------------------------------------------------------

def test_manifest_cycle_detected_at_construction():
    with pytest.raises(ValueError, match=r"dependency cycle: .*a.*b.*a"):
        ActionManifest((FunctionSpec("a", None, ("b",)),
                        FunctionSpec("b", None, ("a",))))


def test_manifest_self_cycle_named():
    with pytest.raises(ValueError, match=r"dependency cycle: x -> x"):
        ActionManifest((FunctionSpec("x", None, ("x",)),))


def test_workflow_graph_cycle_named():
    with pytest.raises(ValueError, match="dependency cycle"):
        WorkflowGraph(name="bad", tasks=("a", "b", "c"),
                      means=(1.0, 1.0, 1.0),
                      deps=(("c",), ("a",), ("b",)))


def test_kahn_order_matches_declaration_preference():
    order = kahn_order({"s": (), "m1": ("s",), "m0": ("s",),
                        "r": ("m0", "m1")})
    assert order == ["s", "m1", "m0", "r"]
    man = ActionManifest((FunctionSpec("a", None, ()),
                          FunctionSpec("b", None, ("a",))))
    assert validate_acyclic(man) == ["a", "b"]


def test_manifest_spec_index():
    man = ActionManifest((FunctionSpec("a", None, ()),
                          FunctionSpec("b", None, ("a",))))
    assert man.spec("b").dependencies == ("a",)
    with pytest.raises(KeyError):
        man.spec("nope")


# ------------------------------------------------------------------
# combinator units
# ------------------------------------------------------------------

def test_fanout_suffixes_lane_names():
    g = compile_spec(fanout(task("map", 700.0), 4), name="m")
    assert g.tasks == ("map0", "map1", "map2", "map3")
    assert g.deps == ((), (), (), ())


def test_chain_links_lanewise_on_matching_rank():
    g = compile_spec(chain(fanout(task("a"), 3), fanout(task("b"), 3)),
                     name="lanes")
    assert g.dep_map() == {"a0": (), "a1": (), "a2": (),
                           "b0": ("a0",), "b1": ("a1",), "b2": ("a2",)}


def test_barrier_forces_all_to_all_join():
    g = compile_spec(chain(fanout(task("a"), 3), barrier(),
                           fanout(task("b"), 3)), name="sync")
    assert g.deps[g.index["b1"]] == ("a0", "a1", "a2")
    assert g.stage_depth() == 1


def test_branch_keeps_parts_independent():
    g = compile_spec(branch(task("x"), task("y")), name="br")
    assert g.deps == ((), ())
    assert g.levels() == ((0, 1),)


def test_chain_mismatched_ranks_fan_in():
    g = compile_spec(chain(fanout(task("m"), 4), task("r")), name="fi")
    assert g.deps[g.index["r"]] == ("m0", "m1", "m2", "m3")


def test_conditional_compiles_select_masks():
    g = compile_spec(
        chain(conditional(task("v"), then=task("go"), orelse=task("no")),
              task("fin")), name="cond")
    v, go, no, fin = (g.index[t] for t in ("v", "go", "no", "fin"))
    assert g.cond_guard[go] == v and g.cond_sense[go] is True
    assert g.cond_guard[no] == v and g.cond_sense[no] is False
    assert g.cond_guard[v] == -1 and g.cond_guard[fin] == -1
    assert set(g.deps[go]) == {"v"} and set(g.deps[no]) == {"v"}
    assert set(g.deps[fin]) == {"go", "no"}
    assert g.has_conditionals and g.cond_static is not None
    flat = g.flatten()
    assert not flat.has_conditionals and flat.deps == g.deps


def test_nested_conditional_rejected():
    inner = conditional(task("g2"), then=task("t2"))
    with pytest.raises(ValueError, match="nested conditional"):
        compile_spec(conditional(task("g1"), then=inner), name="nest")


def test_barrier_cannot_open_or_close_chain():
    with pytest.raises(ValueError, match="barrier cannot open"):
        compile_spec(chain(barrier(), task("a")), name="b0")
    with pytest.raises(ValueError, match="barrier cannot close"):
        compile_spec(chain(task("a"), barrier()), name="b1")


def test_duplicate_task_names_rejected():
    with pytest.raises(ValueError, match="duplicate task names"):
        compile_spec(chain(task("a"), task("a")), name="dup")


def test_graph_is_hashable_static_key():
    g1 = compile_spec(chain(task("a", 1.0), task("b", 2.0)), name="g")
    g2 = compile_spec(chain(task("a", 1.0), task("b", 2.0)), name="g")
    assert g1 == g2 and hash(g1) == hash(g2)
    assert g1.manifest_hash == g2.manifest_hash
    g3 = compile_spec(chain(task("a", 1.0), task("b", 3.0)), name="g")
    assert g3.manifest_hash != g1.manifest_hash


# ------------------------------------------------------------------
# golden compiled-IR snapshots: representation identity with the
# hand-rolled encodings the compiler replaced
# ------------------------------------------------------------------

def test_wordcount_ir_golden():
    from repro.sim.workloads import wordcount_graph
    g = wordcount_graph()
    assert g.name == "wordcount"
    assert g.tasks == ("split", "map0", "map1", "map2", "map3", "reduce")
    assert g.means == (300.0, 700.0, 700.0, 700.0, 700.0, 420.0)
    assert g.deps == ((),) + (("split",),) * 4 + (
        ("map0", "map1", "map2", "map3"),)
    assert g.cond_guard == (-1,) * 6
    assert g.levels() == ((0,), (1, 2, 3, 4), (5,))
    assert g.member_sequences(2).tolist() == [[0, 1, 2, 3, 4, 5],
                                              [0, 2, 3, 4, 1, 5]]


def test_thumbnail_ir_golden():
    from repro.sim.workloads import thumbnail_graph, thumbnail_stock_graph
    g = thumbnail_graph()
    assert g.tasks == ("download", "thumb0", "thumb1", "thumb2", "thumb3")
    assert g.means == (480.0, 800.0, 800.0, 800.0, 800.0)
    assert g.deps == ((),) + (("download",),) * 4
    s = thumbnail_stock_graph()
    assert s.name == "thumbnail"
    assert s.tasks == ("thumb0", "thumb1", "thumb2", "thumb3")
    assert s.deps == ((),) * 4


def test_bank_graphs_compile_shapes():
    from repro.sim.workloads import etl_graph, mapreduce_graph
    g = etl_graph(6)
    assert g.tasks == ("ingest", "validate", "xform0", "xform1", "xform2",
                       "xform3", "xform4", "xform5", "load", "quarantine",
                       "commit")
    v = g.index["validate"]
    assert all(g.cond_guard[g.index[f"xform{i}"]] == v for i in range(6))
    assert g.cond_sense[g.index["load"]] is True
    assert g.cond_sense[g.index["quarantine"]] is False
    assert set(g.deps[g.index["commit"]]) == {"load", "quarantine"}
    m = mapreduce_graph(4, 2)
    assert m.deps[m.index["reduce0"]] == ("map0", "map1", "map2", "map3")
    assert m.deps[m.index["reduce1"]] == ("map0", "map1", "map2", "map3")
    assert m.stage_depth() == 3


# ------------------------------------------------------------------
# replay identity: numpy reference oracle vs dag_flight_trial
# ------------------------------------------------------------------

def _reference_replay(z_seq, fail_seq, t_join, seq, dep_mask, slat,
                      cond=None):
    """Pure-numpy replay of ``dag_flight_trial``'s event scan — one event
    at a time, same tie-breaks (first argmin/argmax), same float32
    arithmetic — the semantics oracle the compiled masks must hit
    bitwise."""
    f32 = np.float32
    F, K = z_seq.shape
    z = np.asarray(z_seq, dtype=f32)
    slat = f32(slat)
    has_cond = cond is not None and any(g >= 0 for g in cond[0])
    if has_cond:
        gated = np.array([g >= 0 for g in cond[0]])
        guard = np.array([g if g >= 0 else 0 for g in cond[0]])
        sense = np.array(list(cond[1]))
        gset = {g for g in cond[0] if g >= 0}
        is_guard = np.array([k in gset for k in range(K)])
    done = np.zeros(K, bool)
    attempted = np.zeros((F, K), bool)
    outcome = np.zeros(K, bool)
    cur = np.full(F, -1)
    curfail = np.zeros(F, bool)
    fin = np.asarray(t_join, dtype=f32).copy()
    released = np.zeros(F, bool)
    trel = np.zeros(F, f32)
    finished = False
    ok = False
    t_resp = f32(np.inf)
    for _ in range(F * (K + 1)):
        t = fin.min()
        e = int(fin.argmin())
        any_busy = not np.isinf(t)
        tk = int(cur[e])
        raw_ok = not curfail[e]
        succ = any_busy and tk >= 0 and raw_ok
        if has_cond:
            if any_busy and tk >= 0 and is_guard[tk]:
                succ = True
            if succ:
                outcome[tk] = raw_ok
        done2 = done.copy()
        if succ:
            done2[tk] = True
        if has_cond:
            done2 |= gated & done2[guard] & (outcome[guard] != sense)
        busy = ~np.isinf(fin)
        freed = np.zeros(F, bool)
        if succ:
            freed = (cur == tk) & busy
        if any_busy:
            freed[e] = True
        busy_after = busy & ~freed
        idle = ~busy_after & ~released
        cand = (~done2[seq]) & ~attempted
        has_next = cand.any(axis=1)
        j = np.argmax(cand, axis=1)
        nxt = seq[np.arange(F), j]
        z_next = z[np.arange(F), j]
        f_next = fail_seq[np.arange(F), j]
        can_start = idle & has_next
        for m in range(F):
            if can_start[m] and (dep_mask[nxt[m]] & ~done2).any():
                can_start[m] = False
        start = np.where(np.arange(F) == e, t, f32(t + slat)).astype(f32)
        fin_try = (start + z_next).astype(f32)
        fin = np.where(can_start, fin_try,
                       np.where(busy_after, fin, f32(np.inf))).astype(f32)
        cur = np.where(can_start, nxt, np.where(busy_after, cur, -1))
        curfail = np.where(can_start, f_next,
                           np.where(busy_after, curfail, False))
        for m in range(F):
            if can_start[m]:
                attempted[m, j[m]] = True
        newly_rel = idle & ~has_next
        released = released | newly_rel
        trel = np.where(newly_rel, t, trel).astype(f32)
        complete = bool(done2.all())
        no_busy = bool(np.isinf(fin).all())
        terminal = (complete or no_busy) and not finished
        if terminal:
            trel = np.where(~released, t, trel).astype(f32)
            released[:] = True
            ok = complete
            t_resp = t
            finished = True
        done = done2
    return t_resp, ok, trel


def _random_spec(rng, tag):
    """One random spec assembled from every combinator, acyclic by
    construction; names are unique via ``tag``."""
    n = [0]

    def fresh():
        n[0] += 1
        return f"{tag}t{n[0]}"

    parts = []
    for i in range(rng.integers(1, 4)):
        kind = rng.integers(0, 4)
        if kind == 0:
            parts.append(task(fresh()))
        elif kind == 1:
            parts.append(fanout(task(fresh()), int(rng.integers(2, 4))))
        elif kind == 2:
            parts.append(branch(task(fresh()), task(fresh())))
        else:
            orelse = task(fresh()) if rng.integers(0, 2) else None
            parts.append(conditional(task(fresh()),
                                     then=task(fresh()), orelse=orelse))
        if rng.integers(0, 3) == 0 and len(parts) > 0 and i < 2:
            parts.append(barrier())
    if isinstance(parts[-1], type(barrier())):
        parts.append(task(fresh()))
    return chain(*parts)


def _check_replay_identity(seed):
    jax = pytest.importorskip("jax")
    import jax.numpy as jnp
    from repro.sim.vector_queue import dag_flight_trial
    rng = np.random.default_rng(seed)
    g = compile_spec(_random_spec(rng, f"s{seed}"), name=f"rand{seed}")
    F = int(rng.integers(2, 5))
    K = g.K
    seq = g.member_sequences(F)
    dep = g.dep_mask()
    z = rng.uniform(100.0, 1000.0, (F, K)).astype(np.float32)
    p_fail = float(rng.choice([0.0, 0.3]))
    fail = rng.uniform(size=(F, K)) < p_fail
    t_join = np.sort(rng.uniform(0.0, 50.0, F)).astype(np.float32)
    slat = 0.5
    want = _reference_replay(z, fail, t_join, seq, dep, slat,
                             cond=g.cond_static)
    got = dag_flight_trial(jnp.asarray(z), jnp.asarray(fail),
                           jnp.asarray(t_join), jnp.asarray(seq),
                           jnp.asarray(dep), slat, cond=g.cond_static)
    np.testing.assert_array_equal(np.asarray(got[0]), want[0],
                                  err_msg=f"t_resp seed={seed}")
    assert bool(got[1]) == want[1], f"ok seed={seed}"
    np.testing.assert_array_equal(np.asarray(got[2]), want[2],
                                  err_msg=f"trel seed={seed}")


@pytest.mark.parametrize("seed", range(16))
def test_random_dag_replay_matches_reference(seed):
    """Compiled masks of a random spec replay bitwise-equal to the
    scalar reference oracle — failures and conditional branches
    included (p_fail alternates 0.0/0.3 by seed draw)."""
    _check_replay_identity(seed)


@hypothesis.given(st.integers(min_value=1000, max_value=100000))
@hypothesis.settings(max_examples=15, deadline=None)
def test_random_dag_replay_matches_reference_hypothesis(seed):
    _check_replay_identity(seed)


def test_conditional_routes_guard_failure_to_orelse():
    """Deterministic conditional unit: guard failure cancels the then-arm
    (its tasks never run) and completes through orelse; guard success
    cancels orelse.  Guard failure is routing, not job failure."""
    jax = pytest.importorskip("jax")
    import jax.numpy as jnp
    from repro.sim.vector_queue import dag_flight_trial
    g = compile_spec(
        chain(conditional(task("v"), then=task("go"), orelse=task("no")),
              task("fin")), name="unit")
    F = 2
    seq = g.member_sequences(F)
    dep = g.dep_mask()
    z = np.full((F, g.K), 100.0, dtype=np.float32)
    t_join = np.zeros(F, dtype=np.float32)
    v = g.index["v"]
    for guard_fails in (False, True):
        fail = np.zeros((F, g.K), dtype=bool)
        if guard_fails:
            # fail every member's attempt at the guard (seq-ordered slots)
            for m in range(F):
                fail[m, np.where(seq[m] == v)[0][0]] = True
        t_resp, ok, _ = dag_flight_trial(
            jnp.asarray(z), jnp.asarray(fail), jnp.asarray(t_join),
            jnp.asarray(seq), jnp.asarray(dep), 0.5, cond=g.cond_static)
        assert bool(ok), f"guard_fails={guard_fails}: flight must complete"
        # exactly 3 tasks run serially (v -> arm -> fin); the cancelled
        # arm contributes no service time
        assert 300.0 <= float(t_resp) < 302.0, (guard_fails,
                                                float(t_resp))


# ------------------------------------------------------------------
# workload bank through the engines (agreement + streaming identity)
# ------------------------------------------------------------------

@pytest.mark.parametrize("name", ["etl", "mapreduce"])
def test_bank_scalar_vector_agreement(name):
    """The two new workload-bank graphs replay end-to-end through BOTH
    closed-loop engines and agree — raptor (conditional mask-select live)
    and stock (flattened: both arms run, which is why ETL's stock fail
    rate is large at fail_prob=0.08).  Success-conditioned means, per
    ``QueueResult.summary``."""
    jax = pytest.importorskip("jax")
    from repro.sim.cluster import Cluster
    from repro.sim.experiments import HA, rate_for
    from repro.sim.flights import FlightSim
    from repro.sim.vector_queue import (QueueFlightSim, etl_queue,
                                        mapreduce_queue)
    from repro.sim.workloads import etl_workload, mapreduce_workload
    qwl, swl_fn = ((etl_queue(), etl_workload) if name == "etl"
                   else (mapreduce_queue(), mapreduce_workload))
    vec = QueueFlightSim(qwl, load="medium", seed=0, **HA)
    for raptor in (True, False):
        wl = swl_fn()
        sim = FlightSim(Cluster(seed=7, **HA), wl, raptor=raptor,
                        arrival_rate_hz=rate_for(wl, HA, "medium"),
                        duration_s=1200.0, load="medium", seed=7)
        jobs = sim.run()
        s_mean = float(np.mean([j.response for j in jobs if j.ok]))
        s_fail = float(np.mean([not j.ok for j in jobs]))
        v = vec.run(768, 8, raptor=raptor).summary()
        assert v["mean"] == pytest.approx(s_mean, rel=0.10), (
            f"{name} raptor={raptor}: scalar {s_mean:.0f}ms "
            f"vs vector {v['mean']:.0f}ms")
        assert v["fail_rate"] == pytest.approx(s_fail, abs=0.04)


def test_bank_streaming_oracle_identity():
    jax = pytest.importorskip("jax")
    from repro.sim.experiments import HA
    from repro.sim.streaming import oracle_check
    from repro.sim.vector_queue import QueueFlightSim, etl_queue
    sim = QueueFlightSim(etl_queue(), load="medium", seed=3, block=1, **HA)
    res = oracle_check(sim, n_steps=3, microbatch=16)
    assert res["bitwise"], res


def test_bank_blocked_configs_bitwise_on_conditional():
    """The conditional mask-select path stays block/resolver invariant:
    blocked replay == block=1 oracle bitwise on the ETL graph."""
    jax = pytest.importorskip("jax")
    from repro.sim.vector_queue import QueueFlightSim, etl_queue
    kw = dict(num_workers=8, num_azs=2, seed=5)
    a = QueueFlightSim(etl_queue(), block=1, **kw).run(96, 2)
    b = QueueFlightSim(etl_queue(), block=8, resolver="unrolled",
                       **kw).run(96, 2)
    np.testing.assert_array_equal(np.asarray(a.response_ms),
                                  np.asarray(b.response_ms))
    np.testing.assert_array_equal(np.asarray(a.ok), np.asarray(b.ok))


def test_queue_workload_graph_is_bucket_key():
    """Content-equal compiled graphs hit the same lru cache entry; the
    bucket/bench identity is the graph itself (plus its manifest hash)."""
    jax = pytest.importorskip("jax")
    from repro.sim.vector_queue import _raptor_trial_fn, etl_queue
    q1, q2 = etl_queue(), etl_queue()
    assert q1.graph == q2.graph
    f1 = _raptor_trial_fn(64, 8, 2, 3, q1.graph, "exp", 0.08)
    f2 = _raptor_trial_fn(64, 8, 2, 3, q2.graph, "exp", 0.08)
    assert f1 is f2
    assert q1.graph.manifest_hash == q2.graph.manifest_hash
