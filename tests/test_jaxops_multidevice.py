"""Raptor JAX combinators under a real multi-device mesh.

jax fixes the device count at first init, so these run in a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count=8 (per the dry-run rule:
never set that flag globally for the test process).
"""
import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp
    import numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.core.jaxops import first_finisher, k_of_n_mean, masked_mean
    from repro.launch.mesh import make_mesh
    from repro.models.moe import shard_map

    mesh = make_mesh((4, 2), ("pod", "model"))

    # --- first_finisher: everyone adopts the min-latency member's value ---
    def member(lat, val):
        adopted, winner = first_finisher(val, lat[0], "pod")
        return adopted, jnp.broadcast_to(winner, (1,))

    lats = jnp.array([3.0, 1.0, 2.0, 5.0])
    vals = jnp.arange(4 * 6, dtype=jnp.float32).reshape(4, 6)  # per-pod rows
    f = shard_map(member, mesh, in_specs=(P("pod"), P("pod", None)),
                  out_specs=(P("pod", None), P("pod")))
    adopted, winner = jax.jit(f)(lats, vals)
    a = np.asarray(adopted)
    assert np.all(np.asarray(winner) == 1), winner
    for r in range(4):
        np.testing.assert_allclose(a[r], np.asarray(vals)[1], rtol=1e-6)

    # --- masked_mean: degraded flight drops dead members ---
    def member2(h, val):
        m, n = masked_mean(val, h[0], "pod")
        return m, jnp.broadcast_to(n, (1,))

    health = jnp.array([1.0, 0.0, 1.0, 1.0])
    f2 = shard_map(member2, mesh, in_specs=(P("pod"), P("pod", None)),
                   out_specs=(P("pod", None), P("pod")))
    m, n = jax.jit(f2)(health, vals)
    expect = np.asarray(vals)[[0, 2, 3]].mean(axis=0)
    np.testing.assert_allclose(np.asarray(m)[0], expect, rtol=1e-6)
    assert np.all(np.asarray(n) == 3.0)

    # --- k_of_n_mean: keep the 2 fastest pods ---
    def member3(lat, val):
        return k_of_n_mean(val, lat[0], 2, "pod")

    f3 = shard_map(member3, mesh, in_specs=(P("pod"), P("pod", None)),
                   out_specs=P("pod", None))
    km = jax.jit(f3)(lats, vals)
    expect = np.asarray(vals)[[1, 2]].mean(axis=0)   # lats 1.0 and 2.0
    np.testing.assert_allclose(np.asarray(km)[0], expect, rtol=1e-6)
    print("JAXOPS_OK")
""")


def test_jaxops_on_8_devices():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    r = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                       capture_output=True, text=True, timeout=300,
                       cwd=os.path.dirname(os.path.dirname(__file__)))
    assert "JAXOPS_OK" in r.stdout, r.stdout + r.stderr
