"""Algebraic properties of the factored max-plus block summaries.

The log-depth chain (``scan_core.maxplus_*`` + ``scan="logdepth"``) is
exact only because the summary algebra is: composition of factored
(diag, offset) operators must be associative (so the prefix scan may
bracket freely), apply must be a homomorphism over compose, and the
summary of a concatenated stream must equal the composition of its
blocks' summaries.  Integer-valued float32 operands keep every check
bitwise (float ``+`` is exact on small integers, ``max`` always is);
the production engines only ever emit diag = 0 — pure float max — which
is what keeps ``scan="logdepth"`` bitwise against the sequential oracle
at arbitrary operands too.

Two tiers like the other property modules: hypothesis when installed,
a seeded grid fallback otherwise (shared helpers).
"""
import jax.numpy as jnp
import numpy as np
import pytest

jax = pytest.importorskip("jax")

try:
    import hypothesis
    import hypothesis.strategies as st
except ModuleNotFoundError:  # bare env: property tier skips, grid runs
    from _hypothesis_compat import hypothesis, st

from repro.sim.scan_core import (block_summary, booking_contrib,  # noqa: E402
                                 maxplus_apply, maxplus_compose,
                                 maxplus_identity, maxplus_prefix_entries)


def rand_op(rng, W, lo=-20, hi=20, p_ninf=0.25):
    """Random factored operator with integer-valued float32 parts; the
    offset mixes -inf (the "books nothing there" value) at rate p_ninf."""
    d = rng.integers(lo, hi, W).astype(np.float32)
    b = rng.integers(lo, hi, W).astype(np.float32)
    b = np.where(rng.uniform(size=W) < p_ninf, -np.inf, b)
    return jnp.asarray(d), jnp.asarray(b)


def rand_stream(rng, n, W, M=2):
    """Random booking estimates: worker indices (with dead -1 slots) and
    integer release times, the (widx, rel) shape block_summary consumes."""
    widx = rng.integers(-1, W, (n, M)).astype(np.int32)
    rel = rng.integers(0, 1000, (n, M)).astype(np.float32)
    rel = np.where(widx < 0, -np.inf, rel)
    return jnp.asarray(widx), jnp.asarray(rel)


def check_associative(rng, W):
    f, g, h = (rand_op(rng, W) for _ in range(3))
    left = maxplus_compose(maxplus_compose(f, g), h)
    right = maxplus_compose(f, maxplus_compose(g, h))
    for a, b in zip(left, right):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # and both bracketings act identically on a vector
    wf = jnp.asarray(rng.integers(-20, 20, W).astype(np.float32))
    np.testing.assert_array_equal(
        np.asarray(maxplus_apply(left, wf)),
        np.asarray(maxplus_apply(h, maxplus_apply(g, maxplus_apply(f, wf)))))


def check_apply_homomorphism(rng, W):
    f, g = rand_op(rng, W), rand_op(rng, W)
    wf = jnp.asarray(rng.integers(-20, 20, W).astype(np.float32))
    np.testing.assert_array_equal(
        np.asarray(maxplus_apply(maxplus_compose(f, g), wf)),
        np.asarray(maxplus_apply(g, maxplus_apply(f, wf))))
    ident = maxplus_identity(W)
    for comp in (maxplus_compose(ident, f), maxplus_compose(f, ident)):
        for a, b in zip(comp, f):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def check_summary_of_concat(rng, W, blocks):
    """summarize(concat(blocks)) == compose(summarize(block) for blocks)
    — offset-only operators, exactly the production summaries."""
    parts = [rand_stream(rng, n, W) for n in blocks]
    whole = (jnp.concatenate([p[0] for p in parts]),
             jnp.concatenate([p[1] for p in parts]))
    op = maxplus_identity(W)
    for widx, rel in parts:
        zero = jnp.zeros((W,), jnp.float32)
        op = maxplus_compose(op, (zero, block_summary(W, widx, rel)))
    np.testing.assert_array_equal(
        np.asarray(op[1]), np.asarray(block_summary(W, *whole)))
    # applying the composed operator == folding the raw contributions
    wf = jnp.asarray(rng.integers(0, 50, W).astype(np.float32))
    folded = jnp.max(jnp.concatenate(
        [wf[None], booking_contrib(W, *whole)]), axis=0)
    np.testing.assert_array_equal(
        np.asarray(maxplus_apply(op, wf)), np.asarray(folded))


def check_prefix_entries(rng, W, nb):
    """The associative prefix's entries equal a sequential fold."""
    diag = jnp.stack([rand_op(rng, W)[0] for _ in range(nb)])
    off = jnp.stack([rand_op(rng, W)[1] for _ in range(nb)])
    wf0 = jnp.asarray(rng.integers(-10, 10, W).astype(np.float32))
    entries, wf_out = maxplus_prefix_entries(diag, off, wf0)
    wf = wf0
    for k in range(nb):
        np.testing.assert_array_equal(np.asarray(entries[k]), np.asarray(wf))
        wf = maxplus_apply((diag[k], off[k]), wf)
    np.testing.assert_array_equal(np.asarray(wf_out), np.asarray(wf))


# ------------------------------------------------------------------
# seeded grid tier
# ------------------------------------------------------------------

@pytest.mark.parametrize("seed", range(8))
@pytest.mark.parametrize("W", [1, 4, 15])
def test_maxplus_algebra_grid(seed, W):
    rng = np.random.default_rng(seed)
    check_associative(rng, W)
    check_apply_homomorphism(rng, W)
    check_summary_of_concat(rng, W, blocks=[3, 1, 5, 2])
    check_prefix_entries(rng, W, nb=6)


# ------------------------------------------------------------------
# hypothesis tier
# ------------------------------------------------------------------

@hypothesis.given(seed=st.integers(0, 2**16),
                  W=st.integers(min_value=1, max_value=24))
@hypothesis.settings(max_examples=25, deadline=None)
def test_compose_associative_property(seed, W):
    rng = np.random.default_rng(seed)
    check_associative(rng, W)
    check_apply_homomorphism(rng, W)


@hypothesis.given(seed=st.integers(0, 2**16),
                  W=st.integers(min_value=1, max_value=24),
                  blocks=st.lists(st.integers(min_value=1, max_value=9),
                                  min_size=1, max_size=6))
@hypothesis.settings(max_examples=25, deadline=None)
def test_summary_concat_property(seed, W, blocks):
    rng = np.random.default_rng(seed)
    check_summary_of_concat(rng, W, blocks)
