"""Simulator reproduction of the paper's quantitative claims (Tables 6-7,
Figures 6-8).  Tolerances are wide enough for short sim runs but tight
enough to catch regressions in the mechanisms."""
import numpy as np
import pytest

from repro.sim.experiments import (fig6_scale_effect, fig7_other_workloads,
                                   fig8_reliability, table6_overhead,
                                   table7_keygen)

DUR = 600.0   # shorter than the paper's 30 min; stats are stable enough


@pytest.fixture(scope="module")
def keygen():
    return table7_keygen(duration_s=DUR)


def test_table6_overhead_matches():
    rows = table6_overhead(n=20000)
    assert rows["three_az/medium"]["median"] == pytest.approx(9.0, rel=0.15)
    assert rows["three_az/medium"]["p90"] == pytest.approx(16.0, rel=0.25)
    assert rows["one_az/low"]["median"] == pytest.approx(6.0, rel=0.2)
    # HA deployment costs ~2ms extra median overhead (paper Fig 5a)
    assert (rows["three_az/medium"]["median"]
            > rows["one_az/medium"]["median"])


def test_table7_keygen_stock_calibration(keygen):
    s = keygen["stock"]
    assert s["mean"] == pytest.approx(1335, rel=0.15)
    assert s["median"] == pytest.approx(939, rel=0.15)
    assert s["p90"] == pytest.approx(2887, rel=0.2)


def test_table7_keygen_raptor_prediction(keygen):
    r = keygen["raptor"]
    assert r["mean"] == pytest.approx(864, rel=0.15)
    # the headline: mean ratio ~ 2E[min]/E[max] ~ 0.647-0.667
    assert keygen["mean_ratio"] == pytest.approx(0.65, abs=0.06)


def test_fig6_scale_effect():
    """No benefit at 1-AZ/5-worker scale; full benefit at 3-AZ/15."""
    out = fig6_scale_effect(duration_s=DUR)
    small = out["one_az_5w/medium"]["mean_ratio"]
    large = out["three_az_15w/medium"]["mean_ratio"]
    assert small > 0.90, f"small scale should show ~no benefit, got {small}"
    assert large < 0.75, f"HA scale should show ~2/3 ratio, got {large}"
    assert large < small


def test_fig7_wordcount_and_thumbnail():
    out = fig7_other_workloads(duration_s=DUR)
    wc = out["wordcount"]["mean_ratio"]
    th = out["thumbnail"]["mean_ratio"]
    assert wc < 0.60, f"wordcount should be >40% faster, got {wc}"
    assert 0.85 < th < 1.02, f"thumbnail muted-but-positive, got {th}"


def test_scalar_engine_drivers_still_work():
    """fig6/fig7 default to the vector engine; the scalar driver loops
    remain the validation oracle and must keep producing the same result
    shape and paper-shaped ratios (short window: smoke, not calibration)."""
    out = fig6_scale_effect(duration_s=150.0, engine="scalar")
    assert set(out) == {f"{d}/{l}"
                       for d in ("one_az_5w", "three_az_15w")
                       for l in ("low", "medium", "high")}
    assert out["three_az_15w/medium"]["mean_ratio"] < 0.85
    out7 = fig7_other_workloads(duration_s=150.0, engine="scalar")
    assert out7["wordcount"]["mean_ratio"] < 0.65
    assert 0.8 < out7["thumbnail"]["mean_ratio"] < 1.05


def test_run_pair_reports_failures_separately():
    """Scalar driver accounting: delay summaries are success-conditioned
    and the failed jobs are reported via n_failed, not silently mixed in
    (with fail_prob > 0 a raptor 'response' of a failed job is the
    failure-detection time, not a delay)."""
    from repro.sim.experiments import HA, run_pair
    from repro.sim.workloads import reliability_workload
    res = run_pair(lambda: reliability_workload(2, 0.3), HA, load="low",
                   duration_s=300.0, seed=0)
    for side in ("stock", "raptor"):
        s = res[side]
        assert s["n_failed"] > 0
        assert s["fail_rate"] == pytest.approx(
            s["n_failed"] / (s["n"] + s["n_failed"]))


def test_fig8_reliability():
    out = fig8_reliability(n_jobs_s=400.0)
    for key, row in out.items():
        # simulated failure rates within a few points of theory; the raptor
        # side matches the EXACT 1-(1-p^N)^N job expression (the paper's
        # p^N is its per-task simplification)
        assert row["stock_fail"] == pytest.approx(
            row["theory_stock"], abs=0.08), key
        assert row["raptor_fail"] == pytest.approx(
            row["theory_raptor_exact"], abs=0.04), key
    # the crossover claim: raptor failure falls with N, stock rises
    assert out["n8/p0.2"]["raptor_fail"] < out["n2/p0.2"]["raptor_fail"] + 1e-9
    assert out["n8/p0.2"]["stock_fail"] > out["n2/p0.2"]["stock_fail"] - 1e-9
