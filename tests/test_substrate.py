"""Substrate tests: data pipeline, checkpoint/restore, optimizer, gradient
compression, Raptor redundant-DP weighting, serving engine."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import io as ckpt_io
from repro.configs import get_config, reduced_config
from repro.configs.base import ShapeConfig
from repro.data.synthetic import make_batch
from repro.distributed.collectives import compress_grads
from repro.serving.engine import ServeConfig, ServingEngine, demo_requests
from repro.training.optimizer import OptConfig
from repro.training.raptor_dp import (first_arrival_weights,
                                      redundant_assignment,
                                      signals_to_weights)
from repro.training.step import (StepOptions, init_train_state,
                                 make_train_step)

CFG = reduced_config(get_config("gemma-2b"))
SHAPE = ShapeConfig("t", 32, 4, "train")
OC = OptConfig(warmup_steps=2, total_steps=20)


def test_data_deterministic_and_resumable():
    b1 = make_batch(CFG, SHAPE, 3)
    b2 = make_batch(CFG, SHAPE, 3)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = make_batch(CFG, SHAPE, 4)
    assert not np.array_equal(b1["tokens"], b3["tokens"])


def test_train_loss_decreases():
    """Two alternating batches, enough steps for the synthetic (7x+3) rule
    to become visible — loss must drop substantially from ln(V)."""
    oc = OptConfig(warmup_steps=2, total_steps=60, lr=3e-3, weight_decay=0.0)
    step = jax.jit(make_train_step(CFG, oc, options=StepOptions(remat=False)))
    state = init_train_state(CFG, oc, jax.random.PRNGKey(0))
    batches = [{k: jnp.asarray(v) for k, v in make_batch(CFG, SHAPE, i).items()}
               for i in range(2)]
    losses = []
    for i in range(30):
        state, m = step(state, batches[i % 2])
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.5, (losses[0], losses[-1])


def test_checkpoint_roundtrip(tmp_path):
    state = init_train_state(CFG, OC, jax.random.PRNGKey(0))
    ckpt_io.save(str(tmp_path), 7, state)
    restored, step = ckpt_io.restore(str(tmp_path), state)
    assert step == 7
    for a, b in zip(jax.tree_util.tree_leaves(state),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_gc_and_latest(tmp_path):
    state = {"x": jnp.ones((3,))}
    for s in (1, 2, 3, 4, 5):
        ckpt_io.save(str(tmp_path), s, state, keep=2)
    assert ckpt_io.latest_steps(str(tmp_path)) == [4, 5]


def test_grad_compression_preserves_training():
    oc = OptConfig(warmup_steps=2, total_steps=60, lr=3e-3, weight_decay=0.0)
    batches = [{k: jnp.asarray(v) for k, v in make_batch(CFG, SHAPE, i).items()}
               for i in range(2)]
    for mode in ("bf16", "int8"):
        step = jax.jit(make_train_step(
            CFG, oc, options=StepOptions(remat=False),
            grad_transform=compress_grads(mode)))
        state = init_train_state(CFG, oc, jax.random.PRNGKey(0))
        losses = []
        for i in range(25):
            state, m = step(state, batches[i % 2])
            losses.append(float(m["loss"]))
        assert losses[-1] < losses[0] - 0.4, (mode, losses)


def test_raptor_dp_weights():
    w = signals_to_weights(8, 4, health=np.array([1, 1, 0, 1]))
    assert w.shape == (8,)
    assert w[4] == 0 and w[5] == 0 and w.sum() == 6
    w2 = signals_to_weights(8, 4, latency=np.array([0.2, 0.9, 0.1, 0.5]), k=2)
    assert w2.sum() == 4 and w2[4] == 1.0 and w2[0] == 1.0
    with pytest.raises(RuntimeError):
        signals_to_weights(8, 2, health=np.zeros(2))


def test_masked_step_matches_subset_gradient():
    """Zero-weighting pod 1's samples == training on pod 0's half batch."""
    step = jax.jit(make_train_step(CFG, OC, options=StepOptions(remat=False)))
    state = init_train_state(CFG, OC, jax.random.PRNGKey(0))
    batch = {k: jnp.asarray(v) for k, v in make_batch(CFG, SHAPE, 0).items()}
    wfull = jnp.asarray(signals_to_weights(4, 2, health=np.array([1, 0])))
    s1, m1 = step(state, dict(batch, loss_weight=wfull))
    half = {k: (v[:, :2] if k == "positions" else v[:2])
            for k, v in batch.items()}
    s2, m2 = step(state, half)
    assert float(m1["ce"]) == pytest.approx(float(m2["ce"]), rel=1e-4)


def test_redundant_assignment_rotates():
    a = redundant_assignment(4, 2)
    first_of = {p: [m for m, pp, pos in a if pp == p and pos == 0][0]
                for p in (0, 1)}
    assert first_of[0] != first_of[1]
    w = first_arrival_weights(2, 2, np.array([[0.1, 0.9], [0.5, 0.2]]))
    np.testing.assert_array_equal(w, [[1, 0], [0, 1]])


def test_serving_engine_stock_and_flight():
    params_cfg = reduced_config(get_config("phi3-mini-3.8b"))
    from repro.models import init_params
    params = init_params(params_cfg, jax.random.PRNGKey(0))
    eng = ServingEngine(params_cfg, params,
                        ServeConfig(max_len=24, decode_steps=4,
                                    flight_size=2, mean_jitter_s=0.01))
    batch = demo_requests(params_cfg, batch=2, prompt_len=8)
    r1 = eng.generate(batch)
    assert r1.tokens.shape == (2, 4)
    r2 = eng.generate_flight(batch)
    assert r2.tokens.shape == (2, 4)
    # speculation is exact: same greedy tokens either way
    np.testing.assert_array_equal(r1.tokens, r2.tokens)
    assert r2.flight_report.ok
