"""Property-based invariant tests for the vectorized queue engines.

The closed-loop engine (sim/vector_queue.py) exposes its booking trace
(``QueueFlightSim.trace_run``): per-task ``ready/start/fin/worker`` for the
task-FCFS stock path, per-member ``dispatch/worker/release`` occupancy
intervals for the raptor path.  Every headline number in the reproduction
is a statistic of these schedules, so the schedules themselves must satisfy
the queue invariants *pointwise*, not just on average:

* no task starts before its ready time (stock) / no member dispatches
  before its job arrives (raptor);
* no worker runs two tasks at once (occupancy intervals are disjoint);
* work conservation: an idle worker never coexists with a ready-but-waiting
  task under FCFS (for raptor, excluding the waiting flight's own members —
  placement is whole-flight atomic, see vector_queue.py);
* makespan is monotone in worker count.

Two tiers: ``hypothesis``-driven tests when the package is installed, and a
seeded grid of the same invariant checks that runs on bare environments
(the checks are shared helpers, so both tiers exercise identical logic).

Seed convention (applies to every sim test module): all randomness flows
from explicit integer seeds — ``QueueFlightSim(seed=...)`` derives its jax
PRNG keys from the seed alone, so any failure reproduces bit-for-bit from
the printed parameters.  Never construct a sim without passing ``seed``.
"""
import numpy as np
import pytest

jax = pytest.importorskip("jax")

try:
    import hypothesis
    import hypothesis.strategies as st
except ModuleNotFoundError:  # bare env: hypothesis tier skips, grid runs
    from _hypothesis_compat import hypothesis, st

from repro.sim.vector_queue import (QueueFlightSim, keygen_queue,  # noqa: E402
                                    thumbnail_queue, wordcount_queue)

# float32 schedules run to ~1e6 ms; 1e-3 ms absorbs the scatter round-trip
EPS = 1e-3
WORKLOADS = {"keygen": keygen_queue, "wordcount": wordcount_queue,
             "thumbnail": thumbnail_queue}


# ------------------------------------------------------------------
# shared invariant checkers (used by both the hypothesis and grid tiers)
# ------------------------------------------------------------------

def assert_stock_invariants(tr, W):
    """Task-FCFS invariants on a stock booking trace."""
    for t in range(tr["arrival"].shape[0]):
        r, s, f = (tr[k][t].ravel() for k in ("ready", "start", "fin"))
        w = tr["worker"][t].ravel()
        # the bounded fixed point must have materialized every ready time
        assert np.all(np.isfinite(r)), f"trial {t}: unscheduled tasks"
        # no task starts before its ready time
        early = s < r - EPS
        assert not early.any(), (
            f"trial {t}: task starts {r[early] - s[early]}ms early")
        # no worker runs two tasks at once
        for wk in range(W):
            iv = np.stack([s[w == wk], f[w == wk]], axis=1)
            iv = iv[np.argsort(iv[:, 0])]
            gap = iv[1:, 0] - iv[:-1, 1]
            assert np.all(gap >= -EPS), (
                f"trial {t}: worker {wk} double-booked by {-gap.min()}ms")
        # work conservation: a waiting task implies every worker is busy
        # for the whole wait (checked at the midpoint of the wait)
        for i in np.where(s > r + EPS)[0]:
            tt = 0.5 * (r[i] + s[i])
            busy = np.unique(w[(s <= tt) & (f > tt)])
            assert len(busy) == W, (
                f"trial {t}: task {i} waits at {tt}ms while "
                f"{W - len(busy)} workers idle")


def assert_raptor_invariants(tr, W):
    """Worker-occupancy invariants on a raptor placement trace."""
    T, J, F = tr["dispatch"].shape
    for t in range(T):
        arr, d = tr["arrival"][t], tr["dispatch"][t]
        w, rel = tr["worker"][t], tr["release"][t]
        # a flight whose race ended before a member dispatched never took
        # the worker: its occupancy interval is empty, not negative
        end = np.maximum(d, rel)
        # no member dispatches before its job arrives
        assert np.all(d >= arr[:, None] - EPS), f"trial {t}"
        # HA placement books distinct workers per flight
        for j in range(J):
            assert len(set(w[j])) == F, f"trial {t} job {j}: shared worker"
        # no worker runs two members at once
        for wk in range(W):
            iv = np.stack([d[w == wk], end[w == wk]], axis=1)
            iv = iv[np.argsort(iv[:, 0])]
            gap = iv[1:, 0] - iv[:-1, 1]
            assert np.all(gap >= -EPS), (
                f"trial {t}: worker {wk} double-booked by {-gap.min()}ms")
        # work conservation: a queued member implies every worker outside
        # its own flight is busy for the whole wait (members exclude their
        # flight's own workers — whole-flight atomic placement)
        for j, m in zip(*np.where(d > arr[:, None] + EPS)):
            tt = 0.5 * (arr[j] + d[j, m])
            busy = set(w[(d <= tt) & (end > tt)])
            idle = set(range(W)) - busy - set(w[j])
            assert not idle, (
                f"trial {t}: job {j} member {m} waits at {tt}ms "
                f"while workers {sorted(idle)} idle")


def makespans(wl_fn, W, A, load, seed, *, raptor, jobs=192, trials=4):
    sim = QueueFlightSim(wl_fn(), num_workers=W, num_azs=A, load=load,
                         seed=seed)
    tr = sim.trace_run(jobs, trials, raptor=raptor)
    return (tr["arrival"] + tr["response"]).max(axis=1)


# ------------------------------------------------------------------
# seeded grid tier (runs everywhere, incl. bare envs without hypothesis)
# ------------------------------------------------------------------

GRID = [
    # (workload, num_workers, num_azs, load, seed)
    ("keygen", 15, 3, "medium", 0),
    ("keygen", 5, 1, "high", 1),          # saturated 1-AZ deployment
    ("wordcount", 15, 3, "high", 2),      # staged DAG at util 0.75
    ("wordcount", 6, 3, "medium", 3),
    ("thumbnail", 15, 3, "high", 4),
    ("thumbnail", 5, 1, "low", 5),
]


@pytest.mark.parametrize("wl,W,A,load,seed", GRID)
@pytest.mark.parametrize("raptor", [False, True])
def test_queue_invariants_grid(wl, W, A, load, seed, raptor):
    sim = QueueFlightSim(WORKLOADS[wl](), num_workers=W, num_azs=A,
                         load=load, seed=seed)
    tr = sim.trace_run(192, 4, raptor=raptor)
    if raptor:
        assert_raptor_invariants(tr, W)
    else:
        assert_stock_invariants(tr, W)


@pytest.mark.parametrize("wl", ["keygen", "wordcount"])
@pytest.mark.parametrize("raptor", [False, True])
def test_makespan_monotone_in_workers_grid(wl, raptor):
    """Adding workers never lengthens the same arrival stream's makespan.

    Stock is draw-coupled across worker counts (no W-shaped draws), so the
    comparison is per-trial exact; raptor placement re-draws the AZ-shared
    service block when W changes, so the coupling is statistical — the
    small slack absorbs it.
    """
    slack = 1e-5 if not raptor else 0.05
    for seed in (0, 1):
        mk = {W: makespans(WORKLOADS[wl], W, 3, "high", seed,
                           raptor=raptor) for W in (6, 9, 15)}
        for lo, hi in ((6, 9), (9, 15)):
            assert np.all(mk[hi] <= mk[lo] * (1 + slack)), (
                f"seed {seed}: makespan grew {lo}->{hi} workers")


def test_trace_matches_run():
    """trace_run is the SAME replay as run (same keys): the responses it
    reports must equal the measured ones bit-for-bit."""
    sim = QueueFlightSim(wordcount_queue(), load="high", seed=6,
                         num_workers=15, num_azs=3)
    for raptor in (False, True):
        tr = sim.trace_run(128, 3, raptor=raptor)
        res = sim.run(128, 3, raptor=raptor)
        np.testing.assert_array_equal(tr["response"],
                                      np.asarray(res.response_ms))
        # and the trace's own completion times reproduce the response
        if not raptor:
            resp = tr["fin"].max(axis=2) - tr["arrival"]
            np.testing.assert_allclose(resp, tr["response"], rtol=1e-6)


# ------------------------------------------------------------------
# blocked event-replay substrate: block / resolver / scan invariance
# ------------------------------------------------------------------
# block=1 is the sequential oracle scan — bit-for-bit the pre-blocking
# engine, conservative full race budget.  Every blocked configuration
# (sim/scan_core.py: the unrolled chunks and the bounded parallel fixed
# point, plus the tight K-completion race budget the blocked raptor
# replay runs on, chained sequentially or through the associative
# max-plus summary prefix — scan="logdepth") must reproduce it BITWISE,
# so agreement here simultaneously validates the blocking, the fixed
# point's exactness, the tight-budget theorem, and the offset-only
# summary algebra.  Mean/p50/p99 equality follows from the pointwise
# equality but is asserted explicitly (the acceptance shape).

BLOCKED_CONFIGS = [(1, "auto", "auto"),
                   (16, "unrolled", "seq"), (16, "fixpoint", "seq"),
                   (64, "fixpoint", "seq"),
                   (16, "unrolled", "logdepth"),
                   (64, "fixpoint", "logdepth"),
                   (0, "unrolled", "logdepth")]   # 0 = adaptive split


@pytest.mark.parametrize("raptor", [False, True])
def test_blocked_replay_block_size_invariance(raptor):
    """wordcount at util 0.75: staged DAG, the hardest blocked case."""
    base = None
    for block, resolver, scan in BLOCKED_CONFIGS:
        sim = QueueFlightSim(wordcount_queue(), num_workers=15, num_azs=3,
                             load="high", seed=9, block=block,
                             resolver=resolver, scan=scan)
        tr = sim.trace_run(192, 3, raptor=raptor)
        if raptor:
            assert_raptor_invariants(tr, 15)
        else:
            assert_stock_invariants(tr, 15)
        # the traced replay IS the measured one at every block size
        res = sim.run(192, 3, raptor=raptor)
        np.testing.assert_array_equal(tr["response"],
                                      np.asarray(res.response_ms))
        if base is None:
            base = (tr, res.summary())
        else:
            for k in tr:
                np.testing.assert_array_equal(
                    tr[k], base[0][k],
                    err_msg=f"block={block}/{resolver}/{scan}: "
                            f"trace {k} diverged")
            s = res.summary()
            for k in ("mean", "median", "p99"):
                assert s[k] == base[1][k], (block, resolver, scan, k)


def test_blocked_replay_direct_start_invariance():
    """keygen (dep-free, direct-start members) across blocks, run()-level
    bitwise — covers the fast fig6 path incl. the K-event race budget."""
    base = None
    for block, resolver, scan in ((1, "auto", "auto"),
                                  (8, "unrolled", "seq"),
                                  (32, "fixpoint", "seq"),
                                  (32, "unrolled", "logdepth")):
        sim = QueueFlightSim(keygen_queue(), num_workers=15, num_azs=3,
                             load="medium", seed=4, block=block,
                             resolver=resolver, scan=scan)
        r = np.asarray(sim.run(256, 4, raptor=True).response_ms)
        s = np.asarray(sim.run(256, 4, raptor=False).response_ms)
        if base is None:
            base = (r, s)
        else:
            np.testing.assert_array_equal(r, base[0])
            np.testing.assert_array_equal(s, base[1])


def test_blocked_replay_ragged_tail_invariance():
    """Block sizes that do NOT divide the 190-event stream (B ∈ {3, 7,
    48}): the remainder must resolve as one final partial block — a
    phantom (padded) event that books a worker or perturbs the carried
    W-vector shows up bitwise in runs or traces.  Pinned against the
    block=1 oracle on BOTH engines, runs AND traces, both chain modes."""
    jobs, trials = 190, 2
    for raptor in (False, True):
        oracle = QueueFlightSim(keygen_queue(), num_workers=15, num_azs=3,
                                load="medium", seed=7, block=1)
        base = np.asarray(oracle.run(jobs, trials,
                                     raptor=raptor).response_ms)
        base_tr = oracle.trace_run(jobs, trials, raptor=raptor)
        for block in (3, 7, 48):
            for scan in ("seq", "logdepth"):
                sim = QueueFlightSim(keygen_queue(), num_workers=15,
                                     num_azs=3, load="medium", seed=7,
                                     block=block, resolver="unrolled",
                                     scan=scan)
                r = np.asarray(sim.run(jobs, trials,
                                       raptor=raptor).response_ms)
                np.testing.assert_array_equal(
                    r, base,
                    err_msg=f"raptor={raptor} block={block}/{scan}: "
                            f"runs diverged")
                tr = sim.trace_run(jobs, trials, raptor=raptor)
                for k in tr:
                    np.testing.assert_array_equal(
                        tr[k], base_tr[k],
                        err_msg=f"raptor={raptor} block={block}/{scan}: "
                                f"trace {k} diverged")


def test_blocked_replay_with_failures_invariance():
    """fail_prob > 0 exercises the full F*K race budget and the error
    broadcast path through the substrate; blocked must still equal the
    oracle bitwise (responses AND the ok mask) under either chain."""
    import dataclasses
    wl = dataclasses.replace(wordcount_queue(), fail_prob=0.3)
    base = None
    for block, resolver, scan in ((1, "auto", "auto"),
                                  (16, "fixpoint", "seq"),
                                  (16, "unrolled", "seq"),
                                  (16, "unrolled", "logdepth")):
        sim = QueueFlightSim(wl, num_workers=15, num_azs=3, load="medium",
                             seed=2, block=block, resolver=resolver,
                             scan=scan)
        res = sim.run(192, 3, raptor=True)
        r = (np.asarray(res.response_ms), np.asarray(res.ok))
        if base is None:
            base = r
        else:
            np.testing.assert_array_equal(r[0], base[0])
            np.testing.assert_array_equal(r[1], base[1])


def test_fixpoint_pass_bound_with_failures():
    """Whole-stream fixpoint block at fail_prob > 0 under HA placement:
    the bounded pass count (<= block) must reach the exact schedule.
    Regression for the rows-based termination test (ISSUE 6): a dead
    event's worker pick may flap between passes — convergence is judged
    on the observed per-event W-vectors, which must neither stall early
    exit nor mask unconverged observations."""
    import dataclasses
    wl = dataclasses.replace(keygen_queue(), fail_prob=0.25)
    jobs, trials = 96, 3
    oracle = QueueFlightSim(wl, num_workers=15, num_azs=3, load="high",
                            seed=11, block=1)
    base = oracle.run(jobs, trials, raptor=True)
    sim = QueueFlightSim(wl, num_workers=15, num_azs=3, load="high",
                         seed=11, block=jobs, resolver="fixpoint")
    res = sim.run(jobs, trials, raptor=True)
    np.testing.assert_array_equal(np.asarray(res.response_ms),
                                  np.asarray(base.response_ms))
    np.testing.assert_array_equal(np.asarray(res.ok), np.asarray(base.ok))


# ------------------------------------------------------------------
# fault injection: booking invariants + bitwise block invariance
# ------------------------------------------------------------------
# The fault branch reroutes BOTH engines onto attempt-level machinery
# (sim/faults.py tables + sim/policies.py chains) while keeping every
# booking a deterministic function of (worker free-at, exogenous tables)
# — so the block/resolver/scan bitwise guarantee must survive fault
# injection, and the booking traces must satisfy the *fault-aware*
# queue invariants: no double-booking across crash/requeue, no attempt
# started inside a crash outage, no attempt running through a crash,
# and work conservation where "work" counts retried + hedged attempts
# and a crashed-out worker is not idle.

from repro.sim.faults import FaultProfile  # noqa: E402
from repro.sim.policies import RecoveryPolicy  # noqa: E402

FAULTS = FaultProfile(az_mtbf_ms=24_000.0, az_mttr_ms=6_000.0,
                      degraded_inflation=2.0, degraded_fail_prob=0.05,
                      crash_mtbf_ms=300_000.0, crash_restart_ms=2_000.0)
POLICY = RecoveryPolicy(timeout_ms=6_000.0, max_retries=1,
                        backoff_ms=50.0, backoff_jitter=0.5,
                        hedge_ms=2_500.0)


def assert_stock_fault_invariants(tr, W):
    """Attempt-level task-FCFS invariants on a fault-mode stock trace."""
    T = tr["arrival"].shape[0]
    for t in range(T):
        r = tr["ready"][t].reshape(-1)
        s = tr["start"][t].reshape(-1)
        f = tr["fin"][t].reshape(-1)
        w = tr["worker"][t].reshape(-1)
        cs, ce = tr["crash_start"][t], tr["crash_end"][t]
        live = np.isfinite(s)
        # every launched attempt honors its ready time
        assert np.all(s[live] >= r[live] - EPS), f"trial {t}: early start"
        # no attempt starts inside its worker's crash outage, and no
        # attempt runs THROUGH a crash (a crash kills it at the instant)
        for i in np.where(live)[0]:
            wk = w[i]
            inside = (s[i] >= cs[wk] - EPS) & (s[i] < ce[wk] - EPS)
            assert not inside.any(), (
                f"trial {t}: attempt {i} starts inside an outage")
            through = (cs[wk] > s[i] + EPS) & (cs[wk] < f[i] - EPS)
            assert not through.any(), (
                f"trial {t}: attempt {i} runs through a crash")
        # no double-booking across crash/requeue: all attempt intervals
        # on one worker (retries + hedges included) stay disjoint
        for wk in range(W):
            sel = live & (w == wk)
            iv = np.stack([s[sel], f[sel]], axis=1)
            iv = iv[np.argsort(iv[:, 0])]
            gap = iv[1:, 0] - iv[:-1, 1]
            assert np.all(gap >= -EPS), (
                f"trial {t}: worker {wk} double-booked by {-gap.min()}ms")
        # work conservation counting retried/hedged attempts: a waiting
        # attempt implies every worker is busy (with SOME attempt) or
        # crashed out at the midpoint of the wait
        for i in np.where(live & (s > r + EPS))[0]:
            tt = 0.5 * (r[i] + s[i])
            busy = set(w[live & (s <= tt) & (f > tt)])
            down = {wk for wk in range(W)
                    if ((cs[wk] <= tt) & (tt < ce[wk])).any()}
            free = set(range(W)) - busy - down
            assert not free, (
                f"trial {t}: attempt {i} waits at {tt}ms while "
                f"workers {sorted(free)} idle and healthy")


def test_stock_fault_invariants_grid():
    for wl, seed in (("keygen", 0), ("wordcount", 1)):
        sim = QueueFlightSim(WORKLOADS[wl](), num_workers=10, num_azs=3,
                             load="medium", seed=seed, faults=FAULTS,
                             recovery=POLICY)
        tr = sim.trace_run(128, 2, raptor=False)
        assert_stock_fault_invariants(tr, 10)
        # attempt slots beyond the launched chain stay unscheduled
        assert np.isinf(tr["ready"]).any(), "no retry/hedge slot unused?"
        # at least one retry or hedge actually launched (the profile is
        # hot enough that an all-primary run means the wiring is dead)
        assert np.isfinite(tr["ready"][:, :, :, 1:]).any()


def test_raptor_fault_occupancy_invariants():
    """Raptor under faults books whole chains: occupancy intervals must
    stay disjoint and placement all-distinct, same as fault-free."""
    sim = QueueFlightSim(keygen_queue(), num_workers=10, num_azs=3,
                         load="medium", seed=3, faults=FAULTS,
                         recovery=RecoveryPolicy(timeout_ms=6_000.0,
                                                 max_retries=1,
                                                 backoff_ms=50.0))
    tr = sim.trace_run(128, 2, raptor=True)
    assert_raptor_invariants(tr, 10)


def test_blocked_replay_fault_invariance():
    """With faults + policy enabled every blocked/logdepth config must
    stay bitwise-identical to the block=1 oracle — runs AND traces, both
    engines (the tentpole acceptance pin)."""
    wl = keygen_queue(fail_prob=0.01, faults=FAULTS, recovery=POLICY)
    jobs, trials = 96, 2
    for raptor in (False, True):
        oracle = QueueFlightSim(wl, num_workers=10, num_azs=3,
                                load="medium", seed=5, block=1)
        base = np.asarray(oracle.run(jobs, trials,
                                     raptor=raptor).response_ms)
        base_ok = np.asarray(oracle.run(jobs, trials, raptor=raptor).ok)
        base_tr = oracle.trace_run(jobs, trials, raptor=raptor)
        for block, resolver, scan in ((16, "fixpoint", "seq"),
                                      (16, "unrolled", "logdepth"),
                                      (0, "unrolled", "logdepth")):
            sim = QueueFlightSim(wl, num_workers=10, num_azs=3,
                                 load="medium", seed=5, block=block,
                                 resolver=resolver, scan=scan)
            res = sim.run(jobs, trials, raptor=raptor)
            np.testing.assert_array_equal(
                np.asarray(res.response_ms), base,
                err_msg=f"raptor={raptor} block={block}/{resolver}/{scan}")
            np.testing.assert_array_equal(np.asarray(res.ok), base_ok)
            tr = sim.trace_run(jobs, trials, raptor=raptor)
            for k in tr:
                np.testing.assert_array_equal(
                    tr[k], base_tr[k],
                    err_msg=f"raptor={raptor} block={block}/{resolver}/"
                            f"{scan}: trace {k} diverged")


def test_disabled_faults_compile_to_prefault_path():
    """A disabled FaultProfile + default policy must reproduce the
    no-faults engines bitwise — the static elision contract."""
    base = QueueFlightSim(keygen_queue(), num_workers=10, num_azs=3,
                          load="medium", seed=8)
    gated = QueueFlightSim(keygen_queue(faults=FaultProfile(),
                                        recovery=RecoveryPolicy()),
                           num_workers=10, num_azs=3, load="medium",
                           seed=8)
    for raptor in (False, True):
        a = base.run(128, 2, raptor=raptor)
        b = gated.run(128, 2, raptor=raptor)
        np.testing.assert_array_equal(np.asarray(a.response_ms),
                                      np.asarray(b.response_ms))
        np.testing.assert_array_equal(np.asarray(a.ok), np.asarray(b.ok))


@hypothesis.given(
    seed=st.integers(min_value=0, max_value=2**16),
    retries=st.integers(min_value=0, max_value=2),
    hedge=st.booleans(),
    crashes=st.booleans(),
)
@hypothesis.settings(max_examples=6, deadline=None)
def test_stock_fault_invariants_property(seed, retries, hedge, crashes):
    fp = FaultProfile(az_mtbf_ms=20_000.0, az_mttr_ms=5_000.0,
                      degraded_inflation=2.5, degraded_fail_prob=0.08,
                      crash_mtbf_ms=250_000.0 if crashes else 0.0,
                      crash_restart_ms=2_000.0)
    pol = RecoveryPolicy(timeout_ms=5_000.0, max_retries=retries,
                         backoff_ms=40.0,
                         hedge_ms=2_000.0 if hedge else float("inf"))
    sim = QueueFlightSim(keygen_queue(), num_workers=8, num_azs=3,
                         load="medium", seed=seed, faults=fp, recovery=pol)
    tr = sim.trace_run(96, 2, raptor=False)
    assert_stock_fault_invariants(tr, 8)


# ------------------------------------------------------------------
# hypothesis tier (random deployments; skips when hypothesis is absent)
# ------------------------------------------------------------------

@hypothesis.given(
    wl=st.sampled_from(sorted(WORKLOADS)),
    W=st.integers(min_value=4, max_value=20),
    A=st.integers(min_value=1, max_value=4),
    load=st.sampled_from(["low", "medium", "high"]),
    seed=st.integers(min_value=0, max_value=2**16),
    raptor=st.booleans(),
)
@hypothesis.settings(max_examples=12, deadline=None)
def test_queue_invariants_property(wl, W, A, load, seed, raptor):
    sim = QueueFlightSim(WORKLOADS[wl](), num_workers=W, num_azs=A,
                         load=load, seed=seed)
    tr = sim.trace_run(96, 2, raptor=raptor)
    if raptor:
        assert_raptor_invariants(tr, W)
    else:
        assert_stock_invariants(tr, W)


@hypothesis.given(
    wl=st.sampled_from(sorted(WORKLOADS)),
    seed=st.integers(min_value=0, max_value=2**16),
    raptor=st.booleans(),
)
@hypothesis.settings(max_examples=6, deadline=None)
def test_makespan_monotone_property(wl, seed, raptor):
    slack = 1e-5 if not raptor else 0.05
    mk = {W: makespans(WORKLOADS[wl], W, 3, "high", seed, raptor=raptor,
                       jobs=96, trials=2) for W in (6, 12)}
    assert np.all(mk[12] <= mk[6] * (1 + slack))
