"""Closed-loop vectorized queue engine vs the scalar event-driven oracle.

The scalar FlightSim is the trusted reproduction of the paper's tables; the
batched M/G/c engine (sim/vector_queue.py) must agree with it on mean
response and failure rate for the DAG manifests (wordcount, thumbnail)
from low THROUGH high load (the task-FCFS stock rewrite closed the old
util-0.75 gap), and its dependency-masked flight scan must replay an
independent-task manifest identically to the open-loop scan it extends.

Seed convention: all randomness flows from explicit integer seeds — scalar
oracles get ``Cluster(seed=...)`` + ``FlightSim(..., seed=...)``, vector
engines ``QueueFlightSim(seed=...)`` — so every assertion reproduces
bit-for-bit from the source alone.  Scalar and vector seeds are chosen
independently (the engines share no RNG stream); agreement tolerances are
therefore statistical, sized to the windows' own run-to-run noise.
"""
import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from repro.core import analytics as A  # noqa: E402
from repro.sim.cluster import Cluster  # noqa: E402
from repro.sim.experiments import HA, LOW_AVAIL, rate_for  # noqa: E402
from repro.sim.flights import FlightSim  # noqa: E402
from repro.sim.vector import _flight_trial  # noqa: E402
from repro.sim.vector_queue import (QueueFlightSim, dag_flight_trial,  # noqa: E402
                                    keygen_queue, load_sweep,
                                    thumbnail_queue, wordcount_queue)
from repro.sim.workloads import (keygen_workload, thumbnail_workload,  # noqa: E402
                                 wordcount_workload)

JOBS, TRIALS = 1024, 16


def scalar_stats(wl_fn, *, raptor, load, seed=7, duration_s=1800.0,
                 deployment=HA):
    wl = wl_fn()
    sim = FlightSim(Cluster(seed=seed, **deployment), wl, raptor=raptor,
                    arrival_rate_hz=rate_for(wl, deployment, load),
                    duration_s=duration_s, load=load, seed=seed)
    jobs = sim.run()
    resp = np.array([j.response for j in jobs])
    return {"mean": resp.mean(), "p50": np.percentile(resp, 50),
            "p90": np.percentile(resp, 90),
            "p99": np.percentile(resp, 99),
            "fail_rate": float(np.mean([not j.ok for j in jobs]))}


# ------------------------------------------------------------------
# DAG manifests against the oracle at low AND medium load (acceptance)
# ------------------------------------------------------------------

@pytest.mark.parametrize("qwl_fn,swl_fn", [
    (wordcount_queue, wordcount_workload),
    (thumbnail_queue, thumbnail_workload),
])
@pytest.mark.parametrize("load", ["low", "medium"])
def test_dag_agrees_with_scalar(qwl_fn, swl_fn, load):
    vec = QueueFlightSim(qwl_fn(), load=load, seed=0, **HA)
    for raptor in (True, False):
        s = scalar_stats(swl_fn, raptor=raptor, load=load)
        v = vec.run(JOBS, TRIALS, raptor=raptor)
        vs = v.summary()
        assert vs["mean"] == pytest.approx(s["mean"], rel=0.08), (
            f"raptor={raptor}: scalar {s['mean']:.0f}ms "
            f"vs vector {vs['mean']:.0f}ms")
        assert v.fail_rate() == pytest.approx(s["fail_rate"], abs=0.02)


def test_dag_ratio_matches_paper_shape():
    """fig7: wordcount's storage-hop short-circuit is the big win (~0.46),
    thumbnail's data-path reuse a muted one (~0.9)."""
    wc = QueueFlightSim(wordcount_queue(), load="medium", seed=0,
                        **HA).run_pair(JOBS, TRIALS)
    th = QueueFlightSim(thumbnail_queue(), load="medium", seed=0,
                        **HA).run_pair(JOBS, TRIALS)
    assert wc["mean_ratio"] == pytest.approx(0.46, abs=0.08)
    assert th["mean_ratio"] == pytest.approx(0.92, abs=0.06)
    assert wc["mean_ratio"] < th["mean_ratio"] < 1.0


# ------------------------------------------------------------------
# the dependency-masked scan degenerates to the open-loop scan
# ------------------------------------------------------------------

def test_dag_trial_matches_open_loop_on_independent_tasks():
    """For a dep-free manifest with direct start, dag_flight_trial must
    replay byte-for-byte what sim.vector's _flight_trial replays."""
    rng = np.random.default_rng(3)
    F = K = 3
    seq = jnp.array([np.roll(np.arange(K), -m) for m in range(F)])
    dep = jnp.zeros((K, K), dtype=bool)
    f_open = jax.jit(lambda z, f, tj: _flight_trial(z, f, tj, seq, 0.5))
    f_dag = jax.jit(lambda z, f, tj: dag_flight_trial(
        z, f, tj, seq, dep, 0.5, direct_start=True))
    for trial in range(50):
        z = jnp.array(rng.exponential(900.0, (F, K)).astype(np.float32))
        fail = jnp.array(rng.random((F, K)) < 0.2)
        tj = jnp.array(rng.exponential(10.0, (F,)).astype(np.float32))
        t0, ok0 = f_open(z, fail, tj)
        t1, ok1, _ = f_dag(z, fail, tj)
        assert bool(ok0) == bool(ok1), trial
        assert float(t0) == pytest.approx(float(t1), rel=1e-6), trial


def test_dag_trial_respects_dependencies():
    """A chain manifest (a -> b -> c) can never finish faster than the sum
    of its task times, no matter the flight size."""
    rng = np.random.default_rng(5)
    K, F = 3, 3
    seq = jnp.array([[0, 1, 2]] * F)
    dep = jnp.array([[False, False, False],
                     [True, False, False],
                     [False, True, False]])
    z = jnp.array(rng.exponential(500.0, (F, K)).astype(np.float32))
    fail = jnp.zeros((F, K), dtype=bool)
    tj = jnp.zeros((F,))
    t, ok, _ = dag_flight_trial(z, fail, tj, seq, dep, 0.5)
    assert bool(ok)
    critical = sum(float(jnp.min(z[:, j])) for j in range(K))
    assert float(t) >= critical


# ------------------------------------------------------------------
# queue behaviour
# ------------------------------------------------------------------

def test_response_grows_with_load():
    means = {}
    for load in ("low", "medium", "high"):
        sim = QueueFlightSim(keygen_queue(), load=load, seed=0, **HA)
        means[load] = sim.run(JOBS, 8, raptor=True).summary()["mean"]
    assert means["low"] < means["medium"] < means["high"]


def test_failure_rate_survives_queueing():
    """Error broadcast semantics are load-independent: the 1-(1-p^F)^K
    form must hold in the contended regime too."""
    sim = QueueFlightSim(keygen_queue(fail_prob=0.2), load="medium",
                         seed=0, **HA)
    r = sim.run(JOBS, TRIALS, raptor=True)
    assert r.fail_rate() == pytest.approx(
        A.raptor_failure_exact(0.2, 2), abs=0.02)
    s = sim.run(JOBS, TRIALS, raptor=False)
    assert s.fail_rate() == pytest.approx(A.forkjoin_failure(0.2, 2),
                                          abs=0.02)


@pytest.mark.parametrize("extra_passes", [0, 1])
def test_stock_taskfcfs_agrees_at_high_load(extra_passes):
    """THE tentpole regression test: wordcount STOCK at util 0.75.

    The old vector stock path admitted whole jobs FCFS in arrival order and
    read ~4x pessimistic here (ROADMAP known gap); the task-granular
    event replay must track the scalar task-level-FCFS oracle within 10%
    on mean AND p99.  Vector job count matches the scalar 1800s window so
    both see the same number of busy periods.  Covered at BOTH fixed-point
    budgets: the default (converged) and the minimal scan-over-stage-depth
    configuration the queue-stock-taskfcfs bench tier records.
    """
    s = scalar_stats(wordcount_workload, raptor=False, load="high")
    vec = QueueFlightSim(wordcount_queue(), load="high", seed=0,
                         stock_extra_passes=extra_passes, **HA)
    vs = vec.run(int(vec.rate_hz * 1800), TRIALS, raptor=False).summary()
    assert vs["mean"] == pytest.approx(s["mean"], rel=0.10), (
        f"scalar {s['mean']:.0f}ms vs vector {vs['mean']:.0f}ms")
    assert vs["p99"] == pytest.approx(s["p99"], rel=0.10), (
        f"scalar p99 {s['p99']:.0f}ms vs vector {vs['p99']:.0f}ms")


def test_saturated_regime_growth_rates_agree():
    """1-AZ/5-worker at high load is saturated BY the flights (a flight of
    2 doubles per-job worker demand => util ~1.5): backlog grows without
    bound and window means are meaningless (they scale with the window).
    Per the ROADMAP note, compare the backlog *growth rates* — the slope
    of response vs arrival time — between engines instead.
    """
    slopes = []
    for seed in (3, 11):
        wl = keygen_workload()
        sim = FlightSim(Cluster(seed=seed, **LOW_AVAIL), wl, raptor=True,
                        arrival_rate_hz=rate_for(wl, LOW_AVAIL, "high"),
                        duration_s=1800.0, load="high", seed=seed)
        jobs = sim.run()
        slopes.append(np.polyfit([j.t_arrive for j in jobs],
                                 [j.response for j in jobs], 1)[0])
    scal_slope = float(np.mean(slopes))
    vec = QueueFlightSim(keygen_queue(), load="high", seed=0, **LOW_AVAIL)
    tr = vec.trace_run(int(vec.rate_hz * 1800), 32, raptor=True)
    vec_slope = float(np.mean([
        np.polyfit(tr["arrival"][i], tr["response"][i], 1)[0]
        for i in range(tr["arrival"].shape[0])]))
    # both must actually be saturated (backlog growing)...
    assert scal_slope > 0.02 and vec_slope > 0.02
    # ...and grow at the same rate, within the regime's heavy-tailed noise
    # (the scalar slope itself moves ~10% between seeds)
    assert vec_slope == pytest.approx(scal_slope, rel=0.35), (
        f"scalar backlog slope {scal_slope:.4f} vs vector {vec_slope:.4f}")


def test_deadlocked_dag_flights_terminate_and_agree():
    """fail_prob > 0 on a staged DAG: the scalar sim used to poll a dead
    dependency forever (the event queue never drained, so the censored
    jobs could not even be observed); both engines must now terminate
    deadlocked flights with ok=False at their last event and account
    every admitted job — the shared convention the agreement tests
    depend on."""
    import dataclasses
    wl = wordcount_workload()
    wl.fail_prob = 0.35
    sim = FlightSim(Cluster(seed=3, **HA), wl, raptor=True,
                    arrival_rate_hz=rate_for(wl, HA, "low"),
                    duration_s=900.0, load="low", seed=3)
    jobs = sim.run()
    assert jobs and all(j.t_done >= 0 for j in jobs), "censored jobs"
    scal_fail = float(np.mean([not j.ok for j in jobs]))
    assert 0.2 < scal_fail < 0.9          # the regime actually deadlocks
    qwl = dataclasses.replace(wordcount_queue(), fail_prob=0.35)
    vec = QueueFlightSim(qwl, load="low", seed=0, **HA)
    r = vec.run(1024, 8, raptor=True)
    assert np.isfinite(np.asarray(r.response_ms)).all()
    assert r.fail_rate() == pytest.approx(scal_fail, abs=0.04)


def test_scalar_honors_small_stream_latency():
    """The old dependency wait polled at max(slat, 0.1)ms, quantizing
    sub-0.1ms stream latencies away from the vector scan's exact
    broadcast+slat wake (and busy-polling meanwhile).  Waits are now
    event-driven: a tiny slat runs fine and the engines agree."""
    slat = 0.02
    wl = wordcount_workload()
    sim = FlightSim(Cluster(seed=7, **HA), wl, raptor=True,
                    arrival_rate_hz=rate_for(wl, HA, "low"),
                    duration_s=1800.0, load="low",
                    stream_latency_ms=slat, seed=7)
    jobs = sim.run()
    scal_mean = float(np.mean([j.response for j in jobs]))
    vec = QueueFlightSim(wordcount_queue(), load="low", seed=0,
                         stream_latency_ms=slat, **HA)
    vs = vec.run(JOBS, TRIALS, raptor=True).summary()
    assert vs["mean"] == pytest.approx(scal_mean, rel=0.08), (
        f"slat={slat}: scalar {scal_mean:.0f}ms vs vector "
        f"{vs['mean']:.0f}ms")


def test_load_sweep_matches_single_runs():
    """The config-vmapped sweep must reproduce per-config runs exactly
    (same keys, same draws — the vmap is pure batching)."""
    sweep = load_sweep(keygen_queue(), loads=("low", "medium"), jobs=512,
                       trials=8, seed=0, **HA)
    for load in ("low", "medium"):
        solo = QueueFlightSim(keygen_queue(), load=load, seed=0,
                              **HA).run_pair(512, 8)
        assert sweep[load]["raptor"]["mean"] == pytest.approx(
            solo["raptor"]["mean"], rel=1e-4)
        assert sweep[load]["stock"]["mean"] == pytest.approx(
            solo["stock"]["mean"], rel=1e-4)
