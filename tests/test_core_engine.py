"""Threaded Raptor engine tests: speculation, preemption, fault tolerance,
elastic flights (paper §3.2-§3.3)."""
import threading
import time

import pytest

from repro.core.manifest import (ActionManifest, ExecutionContext,
                                 FunctionSpec, parallel, sequential)
from repro.core.scheduler import (Flight, Preempted, RaptorScheduler,
                                  StateStream, TaskContext, TaskResult)


def sleepy(duration, value=None, fail=False):
    def fn(ctx):
        ctx.sleep(duration)
        if fail:
            raise RuntimeError("injected failure")
        return value if value is not None else ctx.task_name
    return fn


def test_flight_completes_all_outputs():
    man = parallel([("a", sleepy(0.02)), ("b", sleepy(0.02))], concurrency=2)
    rep = Flight(man).run(timeout=10)
    assert rep.ok
    assert set(rep.outputs) == {"a", "b"}


def test_preemption_saves_work():
    """One slow, one fast member racing the same tasks: the slow copy must
    be preempted, so total busy time << 2x serial time."""
    ev = threading.Event()

    def fast(ctx):
        ctx.sleep(0.01)
        return "fast"

    def slow(ctx):
        ctx.sleep(2.0)          # would dominate busy time if not preempted
        return "slow"

    calls = {"n": 0}
    lock = threading.Lock()

    def task(ctx):
        with lock:
            calls["n"] += 1
            mine = calls["n"]
        # first claimant is slow, second fast -> fast one wins, slow preempted
        if mine == 1:
            return slow(ctx)
        return fast(ctx)

    man = ActionManifest((FunctionSpec("t", task),), concurrency=2)
    t0 = time.monotonic()
    rep = Flight(man).run(timeout=10)
    elapsed = time.monotonic() - t0
    assert rep.ok
    assert elapsed < 1.0, "flight should finish at the FAST copy's time"
    assert rep.total_busy < 1.5, "slow copy must have been preempted"
    preempted = sum(len(e.preempted) for e in rep.executors)
    assert preempted >= 1


def test_flight_survives_member_failure():
    """p^N semantics: one member fails, the flight still succeeds."""
    state = {"n": 0}
    lock = threading.Lock()

    def flaky(ctx):
        with lock:
            state["n"] += 1
            mine = state["n"]
        if mine == 1:
            raise RuntimeError("member crash")
        ctx.sleep(0.01)
        return "ok"

    man = ActionManifest((FunctionSpec("t", flaky),), concurrency=2)
    rep = Flight(man).run(timeout=10)
    assert rep.ok
    assert rep.outputs["t"] == "ok"
    failed = sum(len(e.failed) for e in rep.executors)
    assert failed == 1


def test_flight_fails_when_all_members_fail():
    man = ActionManifest(
        (FunctionSpec("t", sleepy(0.01, fail=True)),), concurrency=2)
    rep = Flight(man).run(timeout=1.0)
    assert not rep.ok


def test_flight_fails_fast_on_permanent_task_failure():
    """Regression: a task that errors on EVERY member can never complete
    (each member attempts it once), so the flight must fail as soon as the
    last attempt errors — not hang until the full timeout."""
    man = ActionManifest(
        (FunctionSpec("t", sleepy(0.01, fail=True)),), concurrency=3)
    t0 = time.monotonic()
    rep = Flight(man).run(timeout=60.0)
    elapsed = time.monotonic() - t0
    assert not rep.ok
    assert elapsed < 5.0, f"flight burned {elapsed:.1f}s of a 60s timeout"
    assert sum(len(e.failed) for e in rep.executors) == 3


def test_flight_fails_fast_mid_dag():
    """A dead task in the middle of a DAG also fails fast: downstream
    functions can never become runnable."""
    man = ActionManifest((
        FunctionSpec("ok_task", sleepy(0.01)),
        FunctionSpec("dead", sleepy(0.01, fail=True),
                     dependencies=("ok_task",)),
        FunctionSpec("down", sleepy(0.01), dependencies=("dead",)),
    ), concurrency=2)
    t0 = time.monotonic()
    rep = Flight(man).run(timeout=60.0)
    elapsed = time.monotonic() - t0
    assert not rep.ok
    assert elapsed < 10.0
    assert "ok_task" in rep.outputs


# ------------------------------------------------------------------
# StateStream semantics (paper §3.3.4)
# ------------------------------------------------------------------

def _res(name, value=None, error=None, executor=0, t=None):
    return TaskResult(name, value, error,
                      executor, time.monotonic() if t is None else t)


def test_stream_first_result_wins():
    st = StateStream()
    assert st.publish(_res("t", value=1, executor=0)) is True
    assert st.publish(_res("t", value=2, executor=1)) is False
    assert st.completed()["t"].value == 1
    assert st.duplicates == 1


def test_stream_error_then_success_overwrites():
    st = StateStream()
    st.publish(_res("t", error=RuntimeError("boom"), executor=0))
    assert st.visible("t") is None          # errors are never visible
    assert st.publish(_res("t", value=7, executor=1)) is True
    assert st.completed()["t"].value == 7
    assert st.error_count("t") == 1


def test_stream_success_then_error_is_ignored():
    st = StateStream()
    assert st.publish(_res("t", value=3, executor=0)) is True
    st.publish(_res("t", error=RuntimeError("late crash"), executor=1))
    assert st.completed()["t"].value == 3
    # the late error is counted but cannot shadow the success
    assert st.error_count("t") == 1
    assert st.wait_all(["t"], timeout=0.1, dead_after=1) is True


def test_stream_error_count_distinct_executors():
    st = StateStream()
    st.publish(_res("t", error=RuntimeError("a"), executor=0))
    st.publish(_res("t", error=RuntimeError("b"), executor=0))   # same member
    assert st.error_count("t") == 1
    st.publish(_res("t", error=RuntimeError("c"), executor=1))
    assert st.error_count("t") == 2


def test_stream_wait_all_dead_task_returns_early():
    st = StateStream()
    st.publish(_res("t", error=RuntimeError("x"), executor=0))
    st.publish(_res("t", error=RuntimeError("y"), executor=1))
    t0 = time.monotonic()
    assert st.wait_all(["t"], timeout=5.0, dead_after=2) is False
    assert time.monotonic() - t0 < 1.0


def test_stream_latency_gates_visibility():
    st = StateStream(latency=10.0)
    now = time.monotonic()
    st.publish(_res("t", value=1, executor=0, t=now))
    assert st.visible("t", now=now + 1.0) is None       # still in flight
    assert st.visible("t", now=now + 10.5) is not None  # delivered


# ------------------------------------------------------------------
# TaskContext preemption granularity
# ------------------------------------------------------------------

def _ctx():
    return TaskContext("m", "t", 0, ExecutionContext.fresh(), {})


def test_sleep_preempted_within_slice_granularity():
    """ctx.sleep polls the cancel token every slice: a preemption that
    lands mid-sleep must interrupt within a few slices, not at the end."""
    ctx = _ctx()
    threading.Timer(0.03, ctx._cancel.set).start()
    t0 = time.monotonic()
    with pytest.raises(Preempted):
        ctx.sleep(2.0, slice_s=0.002)
    elapsed = time.monotonic() - t0
    assert elapsed < 0.5, f"preemption took {elapsed:.3f}s, want ~0.03s"


def test_sleep_completes_when_not_cancelled():
    ctx = _ctx()
    t0 = time.monotonic()
    ctx.sleep(0.05)
    assert 0.04 <= time.monotonic() - t0 < 0.5
    ctx.checkpoint()                        # no cancel -> no raise


def test_checkpoint_raises_after_cancel():
    ctx = _ctx()
    ctx._cancel.set()
    with pytest.raises(Preempted):
        ctx.checkpoint()
    with pytest.raises(Preempted):
        ctx.sleep(0.01)


def test_elastic_reduced_flight():
    """Paper §3.3.2: fewer available executors -> smaller flight, still ok."""
    man = parallel([("a", sleepy(0.01)), ("b", sleepy(0.01))], concurrency=4)
    rep = Flight(man, size=1).run(timeout=10)
    assert rep.ok
    assert len(rep.executors) == 1


def test_dag_dataflow_through_stream():
    """Outputs flow between chained functions via the state stream."""
    def add_one(ctx):
        ctx.sleep(0.005)
        base = ctx.inputs.get("first", 0)
        return base + 1

    def first(ctx):
        ctx.sleep(0.005)
        return 41

    man = sequential([("first", first), ("second", add_one)], concurrency=2)
    rep = Flight(man).run(timeout=10)
    assert rep.ok
    assert rep.outputs["second"] == 42


def test_scheduler_bounded_pool():
    sched = RaptorScheduler(num_workers=2)
    man = parallel([("a", sleepy(0.01)), ("b", sleepy(0.01))], concurrency=4)
    rep = sched.invoke(man, timeout=10)
    assert rep.ok
    assert len(rep.executors) <= 2     # pool-limited elastic flight
