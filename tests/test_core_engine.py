"""Threaded Raptor engine tests: speculation, preemption, fault tolerance,
elastic flights (paper §3.2-§3.3)."""
import threading
import time

import pytest

from repro.core.manifest import ActionManifest, FunctionSpec, parallel, sequential
from repro.core.scheduler import Flight, RaptorScheduler


def sleepy(duration, value=None, fail=False):
    def fn(ctx):
        ctx.sleep(duration)
        if fail:
            raise RuntimeError("injected failure")
        return value if value is not None else ctx.task_name
    return fn


def test_flight_completes_all_outputs():
    man = parallel([("a", sleepy(0.02)), ("b", sleepy(0.02))], concurrency=2)
    rep = Flight(man).run(timeout=10)
    assert rep.ok
    assert set(rep.outputs) == {"a", "b"}


def test_preemption_saves_work():
    """One slow, one fast member racing the same tasks: the slow copy must
    be preempted, so total busy time << 2x serial time."""
    ev = threading.Event()

    def fast(ctx):
        ctx.sleep(0.01)
        return "fast"

    def slow(ctx):
        ctx.sleep(2.0)          # would dominate busy time if not preempted
        return "slow"

    calls = {"n": 0}
    lock = threading.Lock()

    def task(ctx):
        with lock:
            calls["n"] += 1
            mine = calls["n"]
        # first claimant is slow, second fast -> fast one wins, slow preempted
        if mine == 1:
            return slow(ctx)
        return fast(ctx)

    man = ActionManifest((FunctionSpec("t", task),), concurrency=2)
    t0 = time.monotonic()
    rep = Flight(man).run(timeout=10)
    elapsed = time.monotonic() - t0
    assert rep.ok
    assert elapsed < 1.0, "flight should finish at the FAST copy's time"
    assert rep.total_busy < 1.5, "slow copy must have been preempted"
    preempted = sum(len(e.preempted) for e in rep.executors)
    assert preempted >= 1


def test_flight_survives_member_failure():
    """p^N semantics: one member fails, the flight still succeeds."""
    state = {"n": 0}
    lock = threading.Lock()

    def flaky(ctx):
        with lock:
            state["n"] += 1
            mine = state["n"]
        if mine == 1:
            raise RuntimeError("member crash")
        ctx.sleep(0.01)
        return "ok"

    man = ActionManifest((FunctionSpec("t", flaky),), concurrency=2)
    rep = Flight(man).run(timeout=10)
    assert rep.ok
    assert rep.outputs["t"] == "ok"
    failed = sum(len(e.failed) for e in rep.executors)
    assert failed == 1


def test_flight_fails_when_all_members_fail():
    man = ActionManifest(
        (FunctionSpec("t", sleepy(0.01, fail=True)),), concurrency=2)
    rep = Flight(man).run(timeout=1.0)
    assert not rep.ok


def test_elastic_reduced_flight():
    """Paper §3.3.2: fewer available executors -> smaller flight, still ok."""
    man = parallel([("a", sleepy(0.01)), ("b", sleepy(0.01))], concurrency=4)
    rep = Flight(man, size=1).run(timeout=10)
    assert rep.ok
    assert len(rep.executors) == 1


def test_dag_dataflow_through_stream():
    """Outputs flow between chained functions via the state stream."""
    def add_one(ctx):
        ctx.sleep(0.005)
        base = ctx.inputs.get("first", 0)
        return base + 1

    def first(ctx):
        ctx.sleep(0.005)
        return 41

    man = sequential([("first", first), ("second", add_one)], concurrency=2)
    rep = Flight(man).run(timeout=10)
    assert rep.ok
    assert rep.outputs["second"] == 42


def test_scheduler_bounded_pool():
    sched = RaptorScheduler(num_workers=2)
    man = parallel([("a", sleepy(0.01)), ("b", sleepy(0.01))], concurrency=4)
    rep = sched.invoke(man, timeout=10)
    assert rep.ok
    assert len(rep.executors) <= 2     # pool-limited elastic flight
