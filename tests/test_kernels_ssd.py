"""SSD scan kernel vs the (separately validated) jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    import hypothesis
    import hypothesis.strategies as st
except ModuleNotFoundError:  # bare env: property tests skip, rest still run
    from _hypothesis_compat import hypothesis, st

from repro.kernels.ssd_scan.kernel import ssd_scan
from repro.kernels.ssd_scan.ref import ssd_ref


def make(key, b, s, h, p, g, n):
    ks = jax.random.split(key, 4)
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    B = jax.random.normal(ks[3], (b, s, g, n)) * 0.5
    C = jax.random.normal(jax.random.fold_in(key, 9), (b, s, g, n)) * 0.5
    return x, dt, A, B, C


CASES = [
    (1, 64, 2, 16, 1, 16, 32),
    (2, 128, 4, 32, 1, 32, 64),
    (1, 128, 4, 16, 2, 16, 32),     # multi-group
    (1, 256, 2, 64, 1, 64, 128),    # mamba2-like dims
]


@pytest.mark.parametrize("b,s,h,p,g,n,chunk", CASES)
def test_ssd_matches_ref(b, s, h, p, g, n, chunk):
    x, dt, A, B, C = make(jax.random.PRNGKey(0), b, s, h, p, g, n)
    y, st_ = ssd_scan(x, dt, A, B, C, chunk=chunk, interpret=True)
    yr, str_ = ssd_ref(x, dt, A, B, C, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(np.asarray(st_), np.asarray(str_),
                               atol=2e-4, rtol=2e-4)


def test_ssd_state_carries_between_chunks():
    """With 4 chunks, later outputs depend on earlier chunks' state: zeroing
    the first chunk's input must change later outputs.  dt is scaled small
    so the inter-chunk decay exp(sum dt*A) stays O(1)."""
    x, dt, A, B, C = make(jax.random.PRNGKey(1), 1, 128, 2, 16, 1, 16)
    dt = dt * 0.02
    y1, _ = ssd_scan(x, dt, A, B, C, chunk=32, interpret=True)
    x2 = x.at[:, :32].set(0)
    y2, _ = ssd_scan(x2, dt, A, B, C, chunk=32, interpret=True)
    assert not np.allclose(np.asarray(y1[:, 64:]), np.asarray(y2[:, 64:]))


@hypothesis.given(chunks=st.integers(1, 4), h=st.sampled_from([1, 2, 4]),
                  g=st.sampled_from([1, 2]), seed=st.integers(0, 500))
@hypothesis.settings(max_examples=10, deadline=None)
def test_ssd_property(chunks, h, g, seed):
    if h % g:
        g = 1
    s = 32 * chunks
    x, dt, A, B, C = make(jax.random.PRNGKey(seed), 1, s, h, 16, g, 16)
    y, st_ = ssd_scan(x, dt, A, B, C, chunk=32, interpret=True)
    yr, str_ = ssd_ref(x, dt, A, B, C, chunk=32)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               atol=5e-4, rtol=5e-4)
