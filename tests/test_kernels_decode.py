"""Decode-attention kernel vs oracle: GQA ratios, ring-cache masks, dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    import hypothesis
    import hypothesis.strategies as st
except ModuleNotFoundError:  # bare env: property tests skip, rest still run
    from _hypothesis_compat import hypothesis, st

from repro.kernels.decode_attention.kernel import decode_attention
from repro.kernels.decode_attention.ref import decode_attention_ref


def make(key, b, hq, hkv, sk, d, dtype=jnp.float32, valid=None):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, hq, d), dtype)
    k = jax.random.normal(ks[1], (b, sk, hkv, d), dtype)
    v = jax.random.normal(ks[2], (b, sk, hkv, d), dtype)
    pos = jnp.arange(sk, dtype=jnp.int32)
    if valid is not None:
        pos = jnp.where(jnp.arange(sk) < valid, pos, -1)
    return q, k, v, pos


CASES = [
    (1, 1, 1, 256, 64, None, 0.0),
    (2, 8, 2, 512, 64, None, 0.0),        # GQA 4:1
    (1, 16, 1, 256, 128, None, 0.0),      # MQA
    (2, 4, 4, 512, 64, 300, 0.0),         # partially-filled cache
    (1, 8, 8, 256, 64, None, 50.0),       # softcap
]


@pytest.mark.parametrize("b,hq,hkv,sk,d,valid,cap", CASES)
def test_decode_matches_ref(b, hq, hkv, sk, d, valid, cap):
    q, k, v, pos = make(jax.random.PRNGKey(0), b, hq, hkv, sk, d, valid=valid)
    out = decode_attention(q, k, v, pos, logit_cap=cap, block_k=128,
                           interpret=True)
    ref = decode_attention_ref(q, k, v, pos, logit_cap=cap)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_decode_ring_mask():
    """Scattered invalid slots (ring cache) are excluded exactly."""
    b, hq, hkv, sk, d = 1, 4, 2, 256, 64
    q, k, v, _ = make(jax.random.PRNGKey(1), b, hq, hkv, sk, d)
    rng = np.random.default_rng(0)
    pos = np.arange(sk, dtype=np.int32)
    pos[rng.random(sk) < 0.3] = -1
    pos = jnp.asarray(pos)
    out = decode_attention(q, k, v, pos, block_k=64, interpret=True)
    ref = decode_attention_ref(q, k, v, pos)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("dtype,tol", [(jnp.bfloat16, 2e-2)])
def test_decode_bf16(dtype, tol):
    q, k, v, pos = make(jax.random.PRNGKey(2), 2, 8, 2, 256, 64, dtype)
    out = decode_attention(q, k, v, pos, block_k=128, interpret=True)
    ref = decode_attention_ref(q, k, v, pos)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol, rtol=tol)


@hypothesis.given(hkv=st.sampled_from([1, 2, 4]), rep=st.sampled_from([1, 2, 5]),
                  blocks=st.integers(1, 3), seed=st.integers(0, 1000))
@hypothesis.settings(max_examples=10, deadline=None)
def test_decode_property(hkv, rep, blocks, seed):
    sk = 128 * blocks
    q, k, v, pos = make(jax.random.PRNGKey(seed), 1, hkv * rep, hkv, sk, 32)
    out = decode_attention(q, k, v, pos, block_k=128, interpret=True)
    ref = decode_attention_ref(q, k, v, pos)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=3e-5, rtol=3e-5)
