"""Streaming scheduler service: composition exactness + traffic bank.

The streaming engine's one load-bearing claim is COMPOSITION: N
microbatched steps over a persistent W-state are bitwise one whole-trace
``blocked_event_replay`` of the concatenated event stream.  The tests pin
that on runs AND traces — plain, fail_prob>0, and the full fault branch
(brownouts + crashes + timeout/retry/hedge policy) — across microbatch
sizes, blocked configs, and ragged (padded) tails.  The traffic-bank
tests check the arrival processes' laws (resumability, rate, burstiness,
diurnal phase) and the heavy-tail service family; the M/M/c test anchors
the service's steady-state mean sojourn to queueing theory at low
utilisation.

Seed convention: explicit integer seeds everywhere, as in
tests/test_sim_queue.py — every assertion reproduces from source alone.
"""
import math

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from repro.sim.cluster import OverheadModel, lognormal_params  # noqa: E402
from repro.sim.events import (DiurnalArrivals, MMPPArrivals,  # noqa: E402
                              PoissonArrivals)
from repro.sim.faults import FaultProfile  # noqa: E402
from repro.sim.policies import RecoveryPolicy  # noqa: E402
from repro.sim.streaming import (StreamingScheduler, oracle_check,  # noqa: E402
                                 run_open_load, stock_open_sojourns)
from repro.sim.vector import unit_draws  # noqa: E402
from repro.sim.vector_queue import (QueueFlightSim,  # noqa: E402
                                    exponential_queue, heavytail_queue,
                                    keygen_queue, wordcount_queue)
from repro.sim.workloads import UTIL, arrival_rate_hz  # noqa: E402

FAULTS = FaultProfile(az_mtbf_ms=4_000.0, az_mttr_ms=400.0,
                      degraded_inflation=1.6, degraded_fail_prob=0.08,
                      crash_mtbf_ms=30_000.0, crash_restart_ms=200.0)
POLICY = RecoveryPolicy(timeout_ms=2_500.0, max_retries=1,
                        backoff_ms=20.0, hedge_ms=1_500.0)


# ---------------------------------------------------------------------------
# composition: N streamed microbatches == one whole-trace replay (bitwise)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("block,microbatch", [(1, 16), (8, 16), (16, 5)])
def test_streamed_equals_whole_trace_runs(block, microbatch):
    sim = QueueFlightSim(keygen_queue(), num_workers=12, num_azs=3,
                         load="medium", seed=3, block=block)
    res = oracle_check(sim, n_steps=4, microbatch=microbatch)
    assert res["bitwise"], res


def test_streamed_equals_whole_trace_traces():
    sim = QueueFlightSim(keygen_queue(), num_workers=12, num_azs=3,
                         load="high", seed=4, block=8)
    res = oracle_check(sim, n_steps=3, microbatch=12, trace=True)
    assert res["bitwise"], res
    # every trace column individually, not just the conjunction
    for col in ("resp", "ok", "arrival", "dispatch", "worker", "release"):
        assert res[col], (col, res)


def test_streamed_equals_whole_trace_failprob():
    sim = QueueFlightSim(keygen_queue(fail_prob=0.08), num_workers=9,
                         num_azs=3, load="medium", seed=6, block=8)
    res = oracle_check(sim, n_steps=3, microbatch=10, trace=True)
    assert res["bitwise"], res


def test_streamed_equals_whole_trace_faults_on():
    sim = QueueFlightSim(keygen_queue(), num_workers=9, num_azs=3,
                         load="high", seed=5, block=4,
                         faults=FAULTS, recovery=POLICY)
    res = oracle_check(sim, n_steps=3, microbatch=10, trace=True)
    assert res["bitwise"], res


def test_streamed_dag_manifold():
    sim = QueueFlightSim(wordcount_queue(), num_workers=15, num_azs=3,
                         load="medium", seed=2, block=8)
    res = oracle_check(sim, n_steps=3, microbatch=8)
    assert res["bitwise"], res


def test_padded_tail_leaves_wstate_untouched():
    """A padded (inf-arrival) slot books nothing: the W-state after a
    padded microbatch is bitwise the state after replaying only its live
    prefix (truncate the engine's own drawn event tensors — padding sits
    at the end, so the live prefix is exactly events[:6])."""
    from repro.sim.vector_queue import _raptor_stream_fns
    sim = QueueFlightSim(keygen_queue(), num_workers=8, num_azs=2,
                         load="medium", seed=9, block=1)
    arr = PoissonArrivals(sim.rate_hz, seed=1).take(6)
    eng = StreamingScheduler(sim, microbatch=16, keep_events=True, seed=0)
    eng.submit(arr)
    eng.drain()
    events = eng.concatenated_events()
    truncated = jax.tree_util.tree_map(lambda x: x[:6], events)
    _, _, step = _raptor_stream_fns(
        sim.W, sim.A, sim.flight, sim.wl.graph,
        sim.wl.dist, sim.wl.fail_prob, sim._fp, sim._policy,
        1, "fixpoint", "seq", sim.summary_backend, False)
    wf_live, _ = step(jnp.zeros(sim.W), truncated, eng.env, sim.slat)
    np.testing.assert_array_equal(np.asarray(eng.wf), np.asarray(wf_live))


def test_streaming_monotone_submit_validation():
    sim = QueueFlightSim(keygen_queue(), num_workers=8, num_azs=2, seed=0)
    eng = StreamingScheduler(sim, microbatch=8)
    with pytest.raises(ValueError):
        eng.submit(np.array([5.0, 3.0]))          # unsorted
    with pytest.raises(ValueError):
        eng.submit(np.zeros((2, 2)))              # not 1-D
    with pytest.raises(ValueError):
        eng.submit(np.arange(9, dtype=float))     # overflows microbatch
    with pytest.raises(ValueError):
        StreamingScheduler(sim, microbatch=0)
    with pytest.raises(ValueError):
        StreamingScheduler(sim, pipeline_depth=0)


# ---------------------------------------------------------------------------
# M/M/c sanity: steady-state mean sojourn at low utilisation
# ---------------------------------------------------------------------------

def _erlang_c_wait_ms(lam_per_ms, svc_ms, c):
    a = lam_per_ms * svc_ms                 # offered load (erlangs)
    rho = a / c
    pterms = [a ** k / math.factorial(k) for k in range(c)]
    p_full = (a ** c / (math.factorial(c) * (1 - rho)))
    C = p_full / (sum(pterms) + p_full)     # Erlang-C delay probability
    return C * svc_ms / (c * (1 - rho))


def test_mmc_mean_sojourn_low_util():
    """flight=1, single exp task, rho=1.0 (pure AZ-shared draw => exactly
    exponential service): mean sojourn ~= E[oh] + E[S] + Erlang-C wait."""
    mean_ms = 1000.0
    wl = exponential_queue(num_tasks=1, mean_ms=mean_ms, flight=1)
    sim = QueueFlightSim(wl, num_workers=8, num_azs=1, load="low",
                         rho=1.0, seed=11)
    rep = run_open_load(sim, jobs=6000, microbatch=256, warmup=False,
                        process=PoissonArrivals(sim.rate_hz, seed=3),
                        seed=1)
    mu, sigma = lognormal_params(*OverheadModel.TABLE[(False, "low")])
    e_oh = math.exp(mu + sigma * sigma / 2)
    svc = mean_ms + wl.raptor_stage_ms + e_oh   # worker occupancy per job
    lam = sim.rate_hz / 1000.0                  # per ms
    want = e_oh + mean_ms + wl.raptor_stage_ms + _erlang_c_wait_ms(
        lam, svc, sim.W)
    assert rep.ok_frac == 1.0
    assert abs(rep.mean_ms - want) / want < 0.08, (rep.mean_ms, want)


# ---------------------------------------------------------------------------
# arrival processes: law + resumability
# ---------------------------------------------------------------------------

def test_poisson_take_resumes_the_stream():
    p = PoissonArrivals(50.0, seed=1)
    a, b = p.take(400), p.take(600)
    q = PoissonArrivals(50.0, seed=1)
    np.testing.assert_allclose(np.r_[a, b], q.take(1000))
    assert np.all(np.diff(np.r_[a, b]) >= 0)
    p.reset()
    np.testing.assert_allclose(p.take(400), a)


def test_mmpp_rate_and_burstiness():
    rate = 80.0
    m = MMPPArrivals(rate, burst_factor=8.0, dwell_s=(5.0, 1.0), seed=2)
    x = m.take(60_000)
    measured = 1000.0 * x.size / x[-1]
    assert abs(measured - rate) / rate < 0.05
    # index of dispersion of 100ms-window counts: Poisson -> 1, MMPP >> 1
    cnt = np.histogram(x, bins=np.arange(0.0, x[-1], 100.0))[0]
    iod = cnt.var() / cnt.mean()
    assert iod > 3.0, iod
    pois = PoissonArrivals(rate, seed=2).take(60_000)
    pcnt = np.histogram(pois, bins=np.arange(0.0, pois[-1], 100.0))[0]
    assert iod > 3.0 * pcnt.var() / pcnt.mean()


def test_diurnal_phase_modulation():
    d = DiurnalArrivals(100.0, amplitude=0.6, period_s=10.0, seed=3)
    y = d.take(60_000)
    measured = 1000.0 * y.size / y[-1]
    assert abs(measured - 100.0) / 100.0 < 0.05
    # rising half of the sinusoid (phase [0, 0.5)) must carry more
    # arrivals than the falling half, in the analytic proportion
    ph = (y % d.period_ms) / d.period_ms
    hi = np.mean(ph < 0.5)
    # integral of (1 + a sin(2 pi u)) over [0, .5] = .5 + a/pi
    want_hi = 0.5 + 0.6 / np.pi
    assert abs(hi - want_hi) < 0.02, (hi, want_hi)


def test_arrival_validation():
    with pytest.raises(ValueError):
        PoissonArrivals(0.0)
    with pytest.raises(ValueError):
        PoissonArrivals(float("inf"))
    with pytest.raises(ValueError):
        MMPPArrivals(10.0, burst_factor=0.9)
    with pytest.raises(ValueError):
        MMPPArrivals(10.0, dwell_s=(1.0, -2.0))
    with pytest.raises(ValueError):
        DiurnalArrivals(10.0, amplitude=1.0)
    with pytest.raises(ValueError):
        DiurnalArrivals(10.0, period_s=0.0)
    with pytest.raises(ValueError):
        PoissonArrivals(10.0).take(-1)


# ---------------------------------------------------------------------------
# heavy-tail service family + workload validation
# ---------------------------------------------------------------------------

def test_pareto_unit_draws_mean_and_tail():
    cv = 2.0
    x = np.asarray(unit_draws(jax.random.PRNGKey(0), (200_000,),
                              "pareto", cv))
    assert abs(x.mean() - 1.0) < 0.05
    # heavier tail than exp at matched mean: the power law only separates
    # deep in the tail — P(X > 15) is ~8e-4 for pareto(cv=2) but ~3e-7
    # for exp(1) (0.06 expected draws in 200k)
    e = np.asarray(unit_draws(jax.random.PRNGKey(1), (200_000,), "exp", 1.0))
    assert np.mean(x > 15.0) > 4e-4
    assert np.mean(e > 15.0) < 1e-4
    alpha = 1.0 + math.sqrt(1.0 + 1.0 / (cv * cv))
    assert (x >= (alpha - 1.0) / alpha - 1e-6).all()   # support floor xm


def test_heavytail_queue_streams_bitwise():
    sim = QueueFlightSim(heavytail_queue(cv=2.0), num_workers=10,
                         num_azs=2, load="medium", seed=8, block=8)
    res = oracle_check(sim, n_steps=3, microbatch=10)
    assert res["bitwise"], res


def test_heavytail_factory_validation():
    with pytest.raises(ValueError):
        heavytail_queue(dist="weibull")
    with pytest.raises(ValueError):
        heavytail_queue(cv=0.0)


def test_arrival_rate_hz_validation():
    assert arrival_rate_hz(2.0, 10, "medium") == UTIL["medium"] * 10 / 2.0
    with pytest.raises(ValueError, match="unknown load"):
        arrival_rate_hz(2.0, 10, "extreme")
    with pytest.raises(ValueError):
        arrival_rate_hz(0.0, 10, "medium")
    with pytest.raises(ValueError):
        arrival_rate_hz(2.0, 0, "medium")


# ---------------------------------------------------------------------------
# the sustained-load driver + stock reference
# ---------------------------------------------------------------------------

def test_run_open_load_report_fields():
    sim = QueueFlightSim(keygen_queue(), num_workers=12, num_azs=3,
                         load="medium", seed=1)
    rep = run_open_load(sim, jobs=300, microbatch=64, warmup=True,
                        process=MMPPArrivals(sim.rate_hz, seed=4), seed=2)
    assert rep.jobs == 300
    assert rep.jobs_per_s > 0 and rep.wall_s > 0
    assert rep.p50_ms <= rep.p99_ms
    assert 0.0 <= rep.slo_violation_frac <= 1.0
    assert rep.horizon_ms > 0 and rep.offered_rate_hz > 0
    with pytest.raises(ValueError):
        run_open_load(sim, jobs=0)


def test_stock_open_sojourns_dep_free_only():
    sim = QueueFlightSim(keygen_queue(), num_workers=12, num_azs=3,
                         load="low", seed=1)
    arr = PoissonArrivals(sim.rate_hz, seed=5).take(400)
    resp = stock_open_sojourns(sim, arr, seed=0)
    assert resp.shape == (400,) and (resp > 0).all()
    wsim = QueueFlightSim(wordcount_queue(), num_workers=15, num_azs=3,
                          load="low", seed=1)
    with pytest.raises(ValueError, match="dep-free"):
        stock_open_sojourns(wsim, arr)
