"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, asserting output shapes and no NaNs; plus prefill->decode consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, get_config, reduced_config
from repro.models import decode_step, init_params, loss_fn, prefill

B, S = 2, 32


def make_batch(cfg, key, seq=S):
    ks = jax.random.split(key, 3)
    batch = {"labels": jax.random.randint(ks[0], (B, seq), 0, cfg.vocab_size)}
    if cfg.embedding_inputs:
        batch["embeddings"] = jax.random.normal(ks[1], (B, seq, cfg.d_model), jnp.float32)
    else:
        batch["tokens"] = jax.random.randint(ks[1], (B, seq), 0, cfg.vocab_size)
    if cfg.is_encoder_decoder:
        batch["enc_emb"] = jax.random.normal(ks[2], (B, seq // 2, cfg.d_model), jnp.float32)
    if cfg.mrope:
        pos = jnp.broadcast_to(jnp.arange(seq)[None], (B, seq))
        batch["positions"] = jnp.broadcast_to(pos[None], (3, B, seq))
    return batch


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_train_step_smoke(arch):
    cfg = reduced_config(get_config(arch))
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    batch = make_batch(cfg, jax.random.PRNGKey(1))
    (loss, metrics), grads = jax.value_and_grad(
        lambda p: loss_fn(p, cfg, batch), has_aux=True)(params)
    assert loss.shape == ()
    assert np.isfinite(float(loss)), f"{arch}: loss={loss}"
    leaves = jax.tree_util.tree_leaves(grads)
    assert leaves, "no grads"
    for g in leaves:
        assert np.all(np.isfinite(np.asarray(g, dtype=np.float32))), f"{arch}: NaN grad"


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_prefill_decode_smoke(arch):
    cfg = reduced_config(get_config(arch))
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    batch = make_batch(cfg, jax.random.PRNGKey(1))
    batch.pop("labels")
    enc_out = None
    max_len = S + 4
    logits, cache = prefill(params, cfg, batch, max_len)
    assert logits.shape == (B, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(logits)))
    tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    if cfg.embedding_inputs and not cfg.is_encoder_decoder:
        # vlm backbone still embeds generated tokens through the tied table
        pass
    for _ in range(2):
        logits, cache = decode_step(params, cfg, cache, tok)
        assert logits.shape == (B, cfg.vocab_size)
        assert np.all(np.isfinite(np.asarray(logits))), arch
        tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    assert int(cache["index"]) == S + 2


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_param_count_sanity(arch):
    """Analytic param count should match the instantiated reduced model
    within the tolerance of small non-matrix params (norms, biases)."""
    cfg = reduced_config(get_config(arch))
    params = init_params(cfg, jax.random.PRNGKey(0))
    actual = sum(np.prod(l.shape) for l in jax.tree_util.tree_leaves(params))
    analytic = cfg.param_counts()["total"]
    assert actual > 0 and analytic > 0
    # small models are dominated by embeddings; allow generous tolerance
    assert abs(actual - analytic) / actual < 0.35, (arch, actual, analytic)
