"""Device-sharded sweep driver (sim/sweeps.py).

Three properties keep the multi-controller sweep path honest:

* **sharding is pure batching** — the same plan over 1/2/8 forced host
  devices must produce bit-identical summaries.  The device count is a
  process-level XLA flag, so the check runs in a subprocess that forces
  ``--xla_force_host_platform_device_count=8`` and compares the sharded
  runs against the single-device one (JSON-exact, i.e. float-bit-exact);
* **bucketing never drops grid points** — every plan partitions its config
  grid per output tag (``SweepPlan.validate``), checked here over random
  grids (hypothesis tier + seeded fallback, same shared helper);
* **thin ports stay equivalent** — sweep_pairs/rate_sweep through the
  driver match the per-config engines (covered by the existing agreement
  tests in test_sim_vector.py/test_sim_queue.py, which now run through
  the plan path by construction).

Seed convention: explicit integer seeds everywhere, as in every sim test
module — reruns are bit-reproducible.
"""
import json
import os
import subprocess
import sys

import pytest

jax = pytest.importorskip("jax")

try:
    import hypothesis
    import hypothesis.strategies as st
except ModuleNotFoundError:  # bare env: hypothesis tier skips, grid runs
    from _hypothesis_compat import hypothesis, st

from repro.sim.sweeps import SweepPlan, open_loop_pair_plan  # noqa: E402
from repro.sim.vector import exponential_vector, pow2_pad  # noqa: E402

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ------------------------------------------------------------------
# sharded == single-device, bit for bit (subprocess: device count is a
# process-level XLA flag)
# ------------------------------------------------------------------

EQUIV_SCRIPT = r"""
import json, os, sys
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=8")
import jax
assert jax.device_count() == 8, jax.devices()
from repro.sim.vector import exponential_vector, sweep_pairs
from repro.sim.vector_queue import keygen_queue, rate_sweep

# sweep_scale's grid shape in miniature: an AZ axis at fixed flight plus a
# flight axis (two pow2 buckets), so padding, bucketing, and the stock
# single-bucket path all cross the shard boundary
grid = ([dict(flight=4, num_azs=a) for a in (1, 2, 3)]
        + [dict(flight=f, num_azs=8) for f in (2, 4)])
wl = exponential_vector(2, 1000.0)
open_runs = {d: sweep_pairs(wl, grid, trials=1000, seed=0, devices=d)
             for d in (1, 2, 8)}
rates = [1.0, 2.0, 3.0, 4.0]
queue_runs = {d: rate_sweep(keygen_queue(), rates, jobs=64, trials=4,
                            seed=0, devices=d)
              for d in (1, 2, 8)}
for d in (2, 8):
    assert json.dumps(open_runs[d], sort_keys=True) == \
        json.dumps(open_runs[1], sort_keys=True), f"open-loop d={d}"
    assert json.dumps(queue_runs[d], sort_keys=True) == \
        json.dumps(queue_runs[1], sort_keys=True), f"closed-loop d={d}"
print("EQUIV-OK")
"""


def test_sharded_runs_bit_identical_across_device_counts():
    """The acceptance check: the same seeds through 1, 2, and 8 forced
    host devices must produce identical summaries — the shard axis is
    pure batching, never a statistical knob."""
    env = dict(os.environ)
    env["PYTHONPATH"] = (os.path.join(REPO, "src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    r = subprocess.run([sys.executable, "-c", EQUIV_SCRIPT], cwd=REPO,
                       capture_output=True, text=True, timeout=1200,
                       env=env)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    assert "EQUIV-OK" in r.stdout


# ------------------------------------------------------------------
# force_host_devices: XLA_FLAGS hygiene (subprocess: the flag and the
# backend-live state are process-level)
# ------------------------------------------------------------------

def _run_snippet(body, extra_env=None):
    env = dict(os.environ)
    env["PYTHONPATH"] = (os.path.join(REPO, "src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    env.pop("XLA_FLAGS", None)
    env.update(extra_env or {})
    return subprocess.run([sys.executable, "-c", body], cwd=REPO,
                          capture_output=True, text=True, timeout=600,
                          env=env)


def test_force_host_devices_appends_to_user_flags():
    """A user-supplied XLA_FLAGS value must survive verbatim — the device
    count flag is appended, never clobbered over it."""
    r = _run_snippet(r"""
import os
from repro.sim.sweeps import force_host_devices
assert force_host_devices(4) == 4
flags = os.environ["XLA_FLAGS"]
assert "--xla_cpu_enable_fast_math=false" in flags, flags
assert "--xla_force_host_platform_device_count=4" in flags, flags
print("APPEND-OK")
""", extra_env={"XLA_FLAGS": "--xla_cpu_enable_fast_math=false"})
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    assert "APPEND-OK" in r.stdout


def test_force_host_devices_respects_user_count():
    """A user-set device-count flag wins: no append, no override."""
    r = _run_snippet(r"""
import os
from repro.sim.sweeps import force_host_devices
assert force_host_devices(8) == 2
assert os.environ["XLA_FLAGS"].count(
    "--xla_force_host_platform_device_count") == 1
print("USER-OK")
""", extra_env={
        "XLA_FLAGS": "--xla_force_host_platform_device_count=2"})
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    assert "USER-OK" in r.stdout


def test_force_host_devices_errors_after_backend_init():
    """Once the backend is live with fewer devices than requested, the
    call cannot take effect — it must raise, not silently unshard."""
    r = _run_snippet(r"""
import jax
n = jax.device_count()  # initializes the backend
from repro.sim.sweeps import force_host_devices
try:
    force_host_devices(n + 7)
except RuntimeError as e:
    assert "backend" in str(e) and "XLA_FLAGS" in str(e), e
    print("RAISE-OK")
else:
    raise SystemExit("expected RuntimeError after backend init")
""")
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    assert "RAISE-OK" in r.stdout


def test_force_host_devices_noop_when_satisfied():
    """Backend already live with enough devices: no error, returns the
    live count (callers size shards on the return value)."""
    r = _run_snippet(r"""
import jax
n = jax.device_count()
from repro.sim.sweeps import force_host_devices
assert force_host_devices(n) == n
print("NOOP-OK")
""")
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    assert "NOOP-OK" in r.stdout


# ------------------------------------------------------------------
# bucketing partitions the grid (shared helper for both tiers)
# ------------------------------------------------------------------

def assert_plan_covers_grid(flights, azs):
    configs = [dict(flight=f, num_azs=a) for f, a in zip(flights, azs)]
    plan = open_loop_pair_plan(exponential_vector(2, 1000.0), configs,
                               trials=16, seed=0)
    for tag in ("raptor", "stock"):
        idxs = sorted(i for t in plan.tasks if t.tag == tag
                      for i in t.idxs)
        assert idxs == list(range(len(configs))), (
            f"{tag} buckets cover {idxs} of {len(configs)} grid points")
    # and every raptor bucket is shaped by its members' pow2 pad
    for t in plan.tasks:
        if t.tag == "raptor":
            pads = {pow2_pad(configs[i]["flight"]) for i in t.idxs}
            assert len(pads) == 1, f"mixed pads {pads} in one bucket"


GRIDS = [
    ([2], [3]),
    ([2, 3, 4, 5, 8, 16], [1, 2, 3, 4, 6, 8]),
    ([7, 7, 7], [1, 1, 8]),
    ([16, 2, 9, 2, 16], [8, 1, 3, 1, 8]),
]


@pytest.mark.parametrize("flights,azs", GRIDS)
def test_plan_bucketing_covers_grid(flights, azs):
    assert_plan_covers_grid(flights, azs)


@hypothesis.given(
    flights=st.lists(st.integers(min_value=1, max_value=32), min_size=1,
                     max_size=24),
    az_seed=st.integers(min_value=0, max_value=2**16),
)
@hypothesis.settings(max_examples=25, deadline=None)
def test_plan_bucketing_covers_grid_property(flights, az_seed):
    import numpy as np
    azs = (np.random.default_rng(az_seed)
           .integers(1, 9, size=len(flights)).tolist())
    assert_plan_covers_grid(flights, azs)


def test_plan_rejects_dropped_grid_points():
    """A hand-corrupted plan (bucket idxs missing a config) must be
    refused at construction, not silently produce short output."""
    plan = open_loop_pair_plan(exponential_vector(2, 1000.0),
                               [dict(flight=2, num_azs=3),
                                dict(flight=4, num_azs=3)],
                               trials=16, seed=0)
    broken = [t if t.tag != "stock"
              else type(t)(t.tag, t.idxs[:-1], t.core, t.key,
                           tuple(a[:-1] for a in t.cfg), t.shared)
              for t in plan.tasks]
    with pytest.raises(ValueError, match="buckets cover"):
        SweepPlan(plan.name, plan.configs, broken, plan.finalize)


def test_plan_run_single_device_matches_per_config_engine():
    """In-process (1 visible device) sanity: the plan path reproduces the
    per-config VectorFlightSim numbers, same as the pre-driver sweep."""
    from repro.sim.vector import VectorFlightSim, sweep_pairs
    wl = exponential_vector(2, 1000.0)
    sweep = sweep_pairs(wl, [dict(flight=2, num_azs=3)], trials=4000,
                        seed=0, devices=1)[0]
    solo = VectorFlightSim(wl, num_azs=3, flight=2, seed=0).run_pair(4000)
    assert sweep["raptor"]["mean"] == pytest.approx(
        solo["raptor"]["mean"], rel=1e-4)
    assert sweep["mean_ratio"] == pytest.approx(solo["mean_ratio"],
                                                abs=1e-3)
