"""DAG / execution-sequence tests, incl. exact reproduction of paper
Tables 1+3 and property-based checks of sequence validity."""
import pytest

try:
    import hypothesis
    import hypothesis.strategies as st
except ModuleNotFoundError:  # bare env: property tests skip, rest still run
    from _hypothesis_compat import hypothesis, st

from repro.core.dag import (execution_sequence, ready_functions,
                            sequences_for_flight, validate_acyclic)
from repro.core.manifest import ActionManifest, ExecutionContext, FunctionSpec


def paper_manifest(concurrency=2):
    """Table 1: fn1 -> {fn2, fn3} -> fn4."""
    return ActionManifest((
        FunctionSpec("fn1"),
        FunctionSpec("fn2", dependencies=("fn1",)),
        FunctionSpec("fn3", dependencies=("fn1",)),
        FunctionSpec("fn4", dependencies=("fn2", "fn3")),
    ), concurrency=concurrency)


def test_table3_sequences():
    man = paper_manifest()
    assert execution_sequence(man, 0) == ["fn1", "fn2", "fn3", "fn4"]
    assert execution_sequence(man, 1) == ["fn1", "fn3", "fn2", "fn4"]


def test_flight_spreads_fanout():
    """4 executors on 4 independent tasks must all start differently."""
    tasks = tuple(FunctionSpec(f"t{i}") for i in range(4))
    man = ActionManifest(tasks, concurrency=4)
    firsts = [execution_sequence(man, i)[0] for i in range(4)]
    assert len(set(firsts)) == 4


def test_cycle_detected():
    with pytest.raises(ValueError):
        m = ActionManifest((
            FunctionSpec("a", dependencies=("b",)),
            FunctionSpec("b", dependencies=("a",))), 1)
        validate_acyclic(m)


def test_unknown_dependency_rejected():
    with pytest.raises(ValueError):
        ActionManifest((FunctionSpec("a", dependencies=("zzz",)),), 1)


def test_ready_functions():
    man = paper_manifest()
    assert ready_functions(man, []) == ("fn1",)
    assert set(ready_functions(man, ["fn1"])) == {"fn2", "fn3"}
    assert ready_functions(man, ["fn1", "fn2", "fn3"]) == ("fn4",)


def test_execution_context_fork():
    ctx = ExecutionContext.fresh()
    f = ctx.fork(3)
    assert f.context_uuid == ctx.context_uuid
    assert f.follower_index == 3
    with pytest.raises(ValueError):
        ctx.fork(0)


@st.composite
def random_dag(draw):
    n = draw(st.integers(2, 8))
    fns = []
    for i in range(n):
        deps = tuple(f"f{j}" for j in range(i)
                     if draw(st.booleans()))
        fns.append(FunctionSpec(f"f{i}", dependencies=deps))
    conc = draw(st.integers(1, 4))
    return ActionManifest(tuple(fns), concurrency=conc)


@hypothesis.given(random_dag(), st.integers(0, 7))
@hypothesis.settings(max_examples=80, deadline=None)
def test_sequence_is_valid_topo_order(man, idx):
    """Property: every executor's sequence covers all functions and never
    runs a function before its dependencies."""
    seq = execution_sequence(man, idx)
    assert sorted(seq) == sorted(man.names)
    seen = set()
    deps = man.dependency_map()
    for name in seq:
        assert all(d in seen for d in deps[name]), (seq, name)
        seen.add(name)


@hypothesis.given(random_dag())
@hypothesis.settings(max_examples=40, deadline=None)
def test_flight_sequences_all_valid(man):
    for seq in sequences_for_flight(man):
        assert sorted(seq) == sorted(man.names)
