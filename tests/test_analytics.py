"""Order-statistics theory + Monte-Carlo agreement (paper §4.2.1 equation)."""
import numpy as np
import pytest

from repro.core import analytics as A


def test_harmonic_and_order_stats():
    assert A.e_min_exp(2) == pytest.approx(0.5)
    assert A.e_max_exp(2) == pytest.approx(1.5)
    assert A.e_max_exp(4) == pytest.approx(1 + 0.5 + 1 / 3 + 0.25)


def test_paper_headline_ratio():
    # 2 * E[min(Z1,Z2)] / E[max(Z1,Z2)] = 2/3
    assert A.response_ratio_paper() == pytest.approx(2 / 3, abs=1e-9)


def test_failure_curves():
    assert A.forkjoin_failure(0.1, 4) == pytest.approx(1 - 0.9 ** 4)
    assert A.raptor_failure(0.1, 4) == pytest.approx(1e-4)
    # raptor failure falls with N; fork-join rises with N (Figure 8)
    for p in (0.05, 0.2):
        rf = [A.raptor_failure(p, n) for n in range(1, 6)]
        ff = [A.forkjoin_failure(p, n) for n in range(1, 6)]
        assert all(a > b for a, b in zip(rf, rf[1:]))
        assert all(a < b for a, b in zip(ff, ff[1:]))


def test_mc_racing_matches_2emin():
    """Racing flight (non-rotated): T = sum of per-task min order stats."""
    s = A.mc_flight_time(2, 2, n_samples=200_000, rotated=False)
    assert s["mean"] == pytest.approx(1.0, rel=0.02)     # 2 * 1/2


def test_mc_rotated_matches_racing_for_2x2():
    """With preemption, rotated sequences achieve the same 2*E[min] as pure
    racing for the 2-task/2-member case — cross-coverage preempts exactly
    like direct racing, so the paper's eqn applies to its mechanism."""
    s = A.mc_flight_time(2, 2, n_samples=20_000, rotated=True)
    assert s["mean"] == pytest.approx(1.0, abs=0.08)


def test_mc_rotated_beats_forkjoin_for_4x4():
    s = A.mc_flight_time(4, 4, n_samples=4_000, rotated=True)
    assert s["mean"] < A.e_max_exp(4)   # 2.083
