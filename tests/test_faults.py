"""Fault-injection + recovery-policy layer (sim/faults.py, sim/policies.py).

Three tiers:

* unit — the interval helpers and chain folds have numpy twins the scalar
  oracle uses; the jnp and np implementations must not drift apart
  (policies.py module docstring), so every helper is tested in lockstep.
* scalar-vs-vector agreement — with brownouts and timeouts active, the
  vector engines must track the scalar oracle on mean, p99 and failure
  rate at low AND high utilization.  Both engines replay equal-length
  windows (the closed-loop transient means response statistics depend on
  window length — the test_sim_queue.py high-load precedent), and the
  scalar side aggregates several seeded windows so the p99 estimate has
  a real tail behind it.  Latency statistics cover successful jobs (the
  vector ``summary()`` convention); failures are compared as a rate.
* live scheduler — core/scheduler.py consumes the same RecoveryPolicy
  knobs duck-typed: retry budgets both rescue flaky tasks and bound the
  dead-task accounting.
"""
import math

import numpy as np
import pytest

try:
    import hypothesis
    import hypothesis.strategies as st
except ModuleNotFoundError:  # bare env: hypothesis tier skips, grid runs
    from _hypothesis_compat import hypothesis, st

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from repro.core.manifest import ActionManifest, FunctionSpec  # noqa: E402
from repro.core.scheduler import RaptorScheduler  # noqa: E402
from repro.sim.cluster import Cluster  # noqa: E402
from repro.sim.experiments import HA  # noqa: E402
from repro.sim.faults import (NO_FAULTS, FaultProfile,  # noqa: E402
                              first_start_in, first_start_in_np,
                              interval_active, interval_active_np, push_out,
                              push_out_np)
from repro.sim.flights import FlightSim  # noqa: E402
from repro.sim.policies import (NO_RECOVERY, RecoveryPolicy,  # noqa: E402
                                can_fail, chain_transform, fold_chain,
                                fold_chain_np)
from repro.sim.vector_queue import QueueFlightSim, keygen_queue  # noqa: E402
from repro.sim.workloads import arrival_rate_hz, keygen_workload  # noqa: E402


# ------------------------------------------------------------------
# unit: interval helpers, np/jnp lockstep
# ------------------------------------------------------------------

def _random_tables(rng, n=6):
    gaps = rng.exponential(3000.0, n)
    downs = rng.exponential(800.0, n)
    ends = np.cumsum(gaps + downs)
    return ends - downs, ends


def test_interval_helpers_np_jnp_lockstep():
    rng = np.random.default_rng(0)
    starts, ends = _random_tables(rng)
    js, je = jnp.asarray(starts), jnp.asarray(ends)
    for t in rng.uniform(0.0, float(ends[-1]) * 1.2, 200):
        assert bool(interval_active(t, js, je)) == \
            interval_active_np(t, starts, ends)
        assert float(push_out(t, js, je)) == \
            pytest.approx(push_out_np(t, starts, ends), rel=1e-6)
        e = t + rng.uniform(0.0, 5000.0)
        assert float(first_start_in(t, e, js)) == \
            pytest.approx(first_start_in_np(t, e, starts), rel=1e-6)


def test_interval_helpers_sentinel_tables():
    inf_s = np.full(1, np.inf)
    assert not interval_active_np(123.0, inf_s, inf_s)
    assert push_out_np(123.0, inf_s, inf_s) == 123.0
    assert first_start_in_np(0.0, 1e9, inf_s) == math.inf


def test_push_out_lands_after_outage():
    starts, ends = np.array([100.0, 500.0]), np.array([200.0, 900.0])
    assert push_out_np(150.0, starts, ends) == 200.0
    assert push_out_np(50.0, starts, ends) == 50.0
    assert push_out_np(600.0, starts, ends) == 900.0


# ------------------------------------------------------------------
# unit: FaultProfile tables
# ------------------------------------------------------------------

def test_profile_flags_and_stationary():
    assert not NO_FAULTS.enabled
    fp = FaultProfile(az_mtbf_ms=24e3, az_mttr_ms=6e3)
    assert fp.has_brownouts and not fp.has_crashes and fp.enabled
    assert fp.stationary_degraded == pytest.approx(0.2)
    assert NO_FAULTS.stationary_degraded == 0.0
    cp = FaultProfile(crash_mtbf_ms=1e5, crash_restart_ms=2e3)
    assert cp.has_crashes and not cp.has_brownouts and cp.enabled


def test_brownout_tables_shapes_and_sentinels():
    rng = np.random.default_rng(1)
    bs, be = NO_FAULTS.brownout_tables_np(rng, 3)
    assert bs.shape == (3, 1) and np.all(np.isinf(bs)) and np.all(
        np.isinf(be))
    fp = FaultProfile(az_mtbf_ms=24e3, az_mttr_ms=6e3, max_intervals=16)
    bs, be = fp.brownout_tables_np(rng, 3)
    assert bs.shape == be.shape == (3, 16)
    assert np.all(bs < be)
    assert np.all(np.diff(bs, axis=1) > 0)
    assert np.all(be[:, :-1] < bs[:, 1:])       # intervals disjoint


def test_correlated_tables_share_one_process():
    rng = np.random.default_rng(2)
    fp = FaultProfile(az_mtbf_ms=24e3, az_mttr_ms=6e3, correlated=True)
    bs, be = fp.brownout_tables_np(rng, 4)
    assert np.array_equal(bs[0], bs[1]) and np.array_equal(bs[0], bs[3])
    assert np.array_equal(be[0], be[2])
    ind = FaultProfile(az_mtbf_ms=24e3, az_mttr_ms=6e3)
    bs2, _ = ind.brownout_tables_np(np.random.default_rng(2), 4)
    assert not np.array_equal(bs2[0], bs2[1])


def test_crash_tables_cover_horizon():
    fp = FaultProfile(crash_mtbf_ms=50e3, crash_restart_ms=2e3,
                      max_crashes=8)
    cs, ce = fp.crash_tables_np(np.random.default_rng(3), 5)
    assert cs.shape == ce.shape == (5, 8)
    assert np.all(ce - cs == pytest.approx(2e3))
    assert fp.coverage_ms() == pytest.approx((50e3 + 2e3) * 8)


# ------------------------------------------------------------------
# unit: RecoveryPolicy
# ------------------------------------------------------------------

def test_policy_properties():
    assert NO_RECOVERY.is_default and not NO_RECOVERY.has_hedge
    assert NO_RECOVERY.chain_attempts == 1 and NO_RECOVERY.stock_attempts == 1
    pol = RecoveryPolicy(timeout_ms=6e3, max_retries=2, backoff_ms=100.0,
                         backoff_jitter=0.5, hedge_ms=2e3)
    assert not pol.is_default and pol.has_hedge
    assert pol.chain_attempts == 3 and pol.stock_attempts == 4
    assert pol.backoff(0, 0.0) == 100.0
    assert pol.backoff(2, 0.0) == 400.0           # exponential
    assert pol.backoff(0, 1.0) == pytest.approx(150.0)   # jitter U[1,1.5)


def test_can_fail_static_gate():
    assert not can_fail(0.0, None, None)
    assert not can_fail(0.0, NO_FAULTS, NO_RECOVERY)
    assert can_fail(0.01, None, None)
    assert can_fail(0.0, None, RecoveryPolicy(timeout_ms=5e3))
    assert can_fail(0.0, FaultProfile(az_mtbf_ms=1e3, az_mttr_ms=1e3,
                                      degraded_fail_prob=0.1), None)
    assert can_fail(0.0, FaultProfile(crash_mtbf_ms=1e5), None)
    # brownouts that only inflate (no elevated error) cannot fail alone
    assert not can_fail(0.0, FaultProfile(az_mtbf_ms=1e3, az_mttr_ms=1e3,
                                          degraded_inflation=2.0), None)


# ------------------------------------------------------------------
# unit: chain folds, jnp vs np lockstep
# ------------------------------------------------------------------

class _StubRng:
    """Feeds fold_chain_np the exact uniforms handed to fold_chain."""

    def __init__(self, seq):
        self.seq = list(seq)

    def random(self):
        return self.seq.pop(0)


@pytest.mark.parametrize("env", ["healthy", "degraded", "crashy"])
def test_fold_chain_np_jnp_lockstep(env):
    fp = FaultProfile(az_mtbf_ms=24e3, az_mttr_ms=6e3,
                      degraded_inflation=2.0, degraded_fail_prob=0.3)
    pol = RecoveryPolicy(timeout_ms=3_000.0, max_retries=2,
                         backoff_ms=100.0)   # jitter 0: np draws the
    # jitter uniform only on failing attempts, jnp always — zero jitter
    # makes the backoff value independent of that stream offset
    inf1 = np.full(1, np.inf)
    envs = {
        "healthy": (inf1, inf1, inf1, inf1),
        "degraded": (np.zeros(1), inf1, inf1, inf1),
        "crashy": (inf1, inf1, np.array([2_500.0, 9_000.0]),
                   np.array([4_000.0, 9_500.0])),
    }
    bs, be, cs, ce = envs[env]
    rng = np.random.default_rng(4)
    for _ in range(60):
        t0 = float(rng.uniform(0.0, 8_000.0))
        z = float(rng.exponential(2_000.0))
        us = rng.uniform(size=5)      # interleaved err/jit/err/jit/err
        u_err = jnp.asarray(us[[0, 2, 4]])
        u_jit = jnp.asarray(us[[1, 3]])
        end_j, fail_j = fold_chain(
            jnp.asarray(t0), jnp.asarray(z), u_err, u_jit,
            jnp.asarray(bs), jnp.asarray(be), jnp.asarray(cs),
            jnp.asarray(ce), policy=pol, faults=fp, base_fail=0.05)
        end_n, fail_n = fold_chain_np(
            t0, z, _StubRng(us), bs, be, cs, ce,
            policy=pol, faults=fp, base_fail=0.05)
        assert bool(fail_j) == bool(fail_n), (env, t0, z, us)
        assert float(end_j) == pytest.approx(end_n, rel=1e-5), (env, t0, z)


def test_chain_transform_is_frozen_env_fold_chain():
    """Open-loop draw transform == fold_chain with the AZ state frozen,
    no crashes, and t0 = 0 (duration and absolute end coincide)."""
    fp = FaultProfile(az_mtbf_ms=24e3, az_mttr_ms=6e3,
                      degraded_inflation=2.5, degraded_fail_prob=0.2)
    pol = RecoveryPolicy(timeout_ms=4_000.0, max_retries=2,
                         backoff_ms=80.0, backoff_jitter=0.4)
    rng = np.random.default_rng(5)
    n = 512
    z = jnp.asarray(rng.exponential(1_500.0, n))
    u_err = jnp.asarray(rng.uniform(size=(n, 3)))
    u_jit = jnp.asarray(rng.uniform(size=(n, 2)))
    inf_t = jnp.full((n, 1), jnp.inf)
    for frozen_deg in (False, True):
        deg = jnp.full(n, frozen_deg)
        # brownout table matching the frozen state for the whole chain
        bs = jnp.zeros((n, 1)) if frozen_deg else inf_t
        be = inf_t
        dur_t, fail_t = chain_transform(z, u_err, u_jit, deg, policy=pol,
                                        faults=fp, base_fail=0.05)
        end_f, fail_f = fold_chain(jnp.zeros(n), z, u_err, u_jit, bs, be,
                                   inf_t, inf_t, policy=pol, faults=fp,
                                   base_fail=0.05)
        assert np.array_equal(np.asarray(fail_t), np.asarray(fail_f))
        np.testing.assert_allclose(np.asarray(dur_t), np.asarray(end_f),
                                   rtol=1e-5)


# ------------------------------------------------------------------
# scalar-vs-vector agreement with brownouts and timeouts active
# ------------------------------------------------------------------
# Crash-free: worker crashes are the one knob where the engines'
# documented placement approximation differs (the vector books the
# merged stream clairvoyantly, the oracle dispatches among currently
# free workers), so the <3% grid exercises brownouts + timeouts — the
# tentpole mechanisms — and crashes are covered by the property tests
# and the looser hypothesis tier below.

AGREE_FAULTS = FaultProfile(az_mtbf_ms=27e3, az_mttr_ms=3e3,
                            degraded_inflation=1.5, degraded_fail_prob=0.05)
AGREE_POLICY = RecoveryPolicy(timeout_ms=8e3, max_retries=1, backoff_ms=50.0)
_WIN_S = 900.0


def _scalar_fault_stats(load, raptor, *, faults, recovery, seeds,
                        win_s=_WIN_S, fail_prob=0.01):
    swl = keygen_workload(fail_prob=fail_prob, faults=faults,
                          recovery=recovery)
    rate = arrival_rate_hz(swl.work_est_ws, HA["num_workers"], load)
    resp, nfail, njobs = [], 0, 0
    for seed in seeds:
        sim = FlightSim(Cluster(seed=seed, **HA), swl, raptor=raptor,
                        arrival_rate_hz=rate, duration_s=win_s, load=load,
                        seed=seed)
        jobs = sim.run()
        resp += [j.response for j in jobs if j.ok]
        nfail += sum(not j.ok for j in jobs)
        njobs += len(jobs)
    r = np.asarray(resp)
    return {"mean": r.mean(), "p99": np.percentile(r, 99),
            "fail_rate": nfail / njobs}


# Per-config (mean, p99) tolerances.  The test is deterministic (fixed
# seeds both sides), so these sit just above the measured gaps:
#   low  raptor  1.4% / 1.0%     low  stock  0.6% / 3.4%
#   high raptor  4.7% / 11.4%    high stock  1.4% / 0.5%
# Three of four configs hold the <3% target on the mean (the low-stock
# p99 bound carries the scalar tail's ~95-sample estimator noise, not
# engine disagreement — the 21k-job high-stock row reads 0.5%).  The
# high-raptor gap is NOT a fault artifact: with faults and policy off
# entirely the same config already measures 5.4% mean / 6.9% p99 — the
# vector raptor books flights clairvoyantly with an arrival-time health/
# prio snapshot while the oracle dispatches members as workers free —
# and the fault layer does not widen it (4.7% < 5.4%).  The bound below
# pins that pre-existing approximation so it cannot silently grow.
_GRID_TOL = {
    ("low", True): (0.03, 0.03),
    ("low", False): (0.03, 0.05),
    ("high", True): (0.08, 0.15),
    ("high", False): (0.03, 0.03),
}


@pytest.mark.parametrize("load", ["low", "high"])
@pytest.mark.parametrize("raptor", [True, False])
def test_fault_agreement_grid(load, raptor):
    s = _scalar_fault_stats(load, raptor, faults=AGREE_FAULTS,
                            recovery=AGREE_POLICY,
                            seeds=(7, 8, 9, 10, 11, 12))
    vec = QueueFlightSim(keygen_queue(fail_prob=0.01, faults=AGREE_FAULTS,
                                      recovery=AGREE_POLICY),
                         load=load, seed=0, **HA)
    v = vec.run(int(vec.rate_hz * _WIN_S), 16, raptor=raptor).summary()
    mean_tol, p99_tol = _GRID_TOL[(load, raptor)]
    assert v["mean"] == pytest.approx(s["mean"], rel=mean_tol), (
        f"{load} raptor={raptor}: scalar mean {s['mean']:.0f}ms "
        f"vs vector {v['mean']:.0f}ms")
    assert v["p99"] == pytest.approx(s["p99"], rel=p99_tol), (
        f"{load} raptor={raptor}: scalar p99 {s['p99']:.0f}ms "
        f"vs vector {v['p99']:.0f}ms")
    assert v["fail_rate"] == pytest.approx(s["fail_rate"], abs=0.01), (
        f"{load} raptor={raptor}: scalar fail {s['fail_rate']:.4f} "
        f"vs vector {v['fail_rate']:.4f}")


@hypothesis.settings(max_examples=3, deadline=None)
@hypothesis.given(mttr=st.floats(2e3, 6e3), infl=st.floats(1.2, 2.2),
                  timeout=st.floats(5e3, 12e3), retries=st.integers(0, 2))
def test_fault_agreement_property(mttr, infl, timeout, retries):
    """Random profiles stay in the same distribution family: STOCK (the
    fault-richest path) at low load, crashes on, looser tolerance — the
    seeded grid above owns the tight bound."""
    fp = FaultProfile(az_mtbf_ms=24e3, az_mttr_ms=mttr,
                      degraded_inflation=infl, degraded_fail_prob=0.05,
                      crash_mtbf_ms=600e3, crash_restart_ms=2e3)
    pol = RecoveryPolicy(timeout_ms=timeout, max_retries=retries,
                         backoff_ms=50.0)
    s = _scalar_fault_stats("low", False, faults=fp, recovery=pol,
                            seeds=(7, 8), win_s=300.0)
    vec = QueueFlightSim(keygen_queue(fail_prob=0.01, faults=fp,
                                      recovery=pol),
                         load="low", seed=0, **HA)
    v = vec.run(int(vec.rate_hz * 300.0), 8, raptor=False).summary()
    assert v["mean"] == pytest.approx(s["mean"], rel=0.15), (
        f"scalar {s['mean']:.0f}ms vs vector {v['mean']:.0f}ms")
    assert v["fail_rate"] == pytest.approx(s["fail_rate"], abs=0.03)


# ------------------------------------------------------------------
# live scheduler: retry budget rescues flakes, bounds dead accounting
# ------------------------------------------------------------------

def test_scheduler_retries_rescue_flaky_task():
    calls = []

    def flaky(ctx):
        calls.append(1)
        if len(calls) < 3:
            raise RuntimeError("transient")
        return "ok"

    man = ActionManifest((FunctionSpec("t", flaky),), concurrency=1)
    sched = RaptorScheduler(num_workers=2)
    rep = sched.invoke(man, timeout=10,
                       recovery=RecoveryPolicy(max_retries=2,
                                               backoff_ms=1.0))
    assert rep.ok and len(calls) == 3


def test_scheduler_dead_after_respects_attempt_budget():
    def always_fails(ctx):
        raise RuntimeError("permanent")

    man = ActionManifest((FunctionSpec("t", always_fails),), concurrency=2)
    sched = RaptorScheduler(num_workers=2)
    # no policy: one error per executor marks the task dead — the flight
    # fails fast instead of burning the timeout
    rep = sched.invoke(man, timeout=10)
    assert not rep.ok and rep.elapsed < 5.0
    # with retries the budget scales: still fails, still fast
    rep = sched.invoke(man, timeout=10,
                       recovery=RecoveryPolicy(max_retries=1,
                                               backoff_ms=1.0))
    assert not rep.ok and rep.elapsed < 5.0
