"""Expert-batched GEMM kernel vs einsum oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    import hypothesis
    import hypothesis.strategies as st
except ModuleNotFoundError:  # bare env: property tests skip, rest still run
    from _hypothesis_compat import hypothesis, st

from repro.kernels.moe_gmm.kernel import expert_matmul
from repro.kernels.moe_gmm.ref import expert_matmul_ref


@pytest.mark.parametrize("e,c,d,f,dtype,tol", [
    (4, 128, 64, 128, jnp.float32, 1e-5),
    (8, 64, 128, 64, jnp.float32, 1e-5),
    (2, 256, 256, 128, jnp.float32, 1e-5),
    (4, 128, 64, 128, jnp.bfloat16, 3e-2),
])
def test_gmm_matches_ref(e, c, d, f, dtype, tol):
    ks = jax.random.split(jax.random.PRNGKey(0), 2)
    buf = jax.random.normal(ks[0], (e, c, d), dtype)
    w = jax.random.normal(ks[1], (e, d, f), dtype)
    out = expert_matmul(buf, w, block_c=64, block_f=64, block_d=64,
                        interpret=True)
    ref = expert_matmul_ref(buf, w)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=tol * d, rtol=tol)


@hypothesis.given(e=st.integers(1, 6), cb=st.integers(1, 3),
                  db=st.integers(1, 3), fb=st.integers(1, 2),
                  seed=st.integers(0, 100))
@hypothesis.settings(max_examples=10, deadline=None)
def test_gmm_property(e, cb, db, fb, seed):
    c, d, f = 32 * cb, 32 * db, 32 * fb
    ks = jax.random.split(jax.random.PRNGKey(seed), 2)
    buf = jax.random.normal(ks[0], (e, c, d))
    w = jax.random.normal(ks[1], (e, d, f))
    out = expert_matmul(buf, w, block_c=32, block_f=32, block_d=32,
                        interpret=True)
    ref = expert_matmul_ref(buf, w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-4, rtol=1e-4)
