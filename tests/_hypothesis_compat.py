"""Fallback stand-ins for ``hypothesis`` on bare environments.

The property-based tests in this suite are a bonus tier: when the real
``hypothesis`` package is installed they run as usual, and when it is not
the suite must still *collect* (the seed environment ships without it).
Importing modules do::

    try:
        import hypothesis
        import hypothesis.strategies as st
    except ModuleNotFoundError:
        from _hypothesis_compat import hypothesis, st

The stub keeps every module-level decorator expression valid —
``@st.composite``, ``@hypothesis.given(...)``, ``@hypothesis.settings(...)``
— while replacing each decorated test with a skip marker.
"""
import pytest

_SKIP_REASON = "hypothesis not installed; property-based tier skipped"


class _AnyStrategy:
    """Permissive stand-in for strategy objects and combinators: every
    attribute is callable and returns another ``_AnyStrategy``, so strategy
    expressions evaluated at collection time never raise."""

    def __call__(self, *args, **kwargs):
        return _AnyStrategy()

    def __getattr__(self, name):
        return _AnyStrategy()


class _StrategiesStub:
    def __getattr__(self, name):
        return _AnyStrategy()


class _HypothesisStub:
    strategies = _StrategiesStub()

    @staticmethod
    def given(*args, **kwargs):
        def deco(fn):
            return pytest.mark.skip(reason=_SKIP_REASON)(fn)
        return deco

    @staticmethod
    def settings(*args, **kwargs):
        return lambda fn: fn

    @staticmethod
    def assume(condition):
        return True

    @staticmethod
    def note(value):
        return None


hypothesis = _HypothesisStub()
st = _StrategiesStub()
