"""Vectorized MC flight sim vs the scalar event-driven oracle + theory.

The scalar FlightSim is the trusted reproduction of the paper's tables; the
vectorized sim must agree with it (open-loop limit: low utilisation) on
mean response and failure rate, and must reproduce the order-statistics
theory it exists to sweep.

Seed convention: every sim/sweep call passes an explicit integer seed
(``VectorFlightSim(seed=...)``, ``sweep_pairs(..., seed=...)``, scalar
``Cluster(seed=...)`` + ``FlightSim(seed=...)``) so reruns are
bit-reproducible; never rely on a default seed.  Scalar and vector streams
are independent, so cross-engine tolerances are statistical.
"""
import functools

import numpy as np
import pytest

from repro.core import analytics as A
from repro.sim.cluster import Cluster
from repro.sim.experiments import HA, rate_for
from repro.sim.flights import FlightSim
from repro.sim.vector import (VectorFlightSim, exponential_vector,
                              keygen_vector, reliability_vector,
                              sweep_pairs)
from repro.sim.workloads import keygen_workload, reliability_workload

TRIALS = 40_000


def scalar_run(wl_fn, *, raptor, seed, duration_s=1800.0, load="low"):
    wl = wl_fn()
    sim = FlightSim(Cluster(seed=seed, **HA), wl, raptor=raptor,
                    arrival_rate_hz=rate_for(wl, HA, load),
                    duration_s=duration_s, load=load, seed=seed)
    return sim.run()


# ------------------------------------------------------------------
# scalar/vector agreement (the satellite acceptance check)
# ------------------------------------------------------------------

def test_keygen_mean_agrees_with_scalar():
    vec = VectorFlightSim(keygen_vector(), num_azs=3, flight=2, load="low",
                          seed=0)
    for raptor in (False, True):
        jobs = scalar_run(keygen_workload, raptor=raptor, seed=3)
        scalar_mean = float(np.mean([j.response for j in jobs]))
        vec_mean = vec.run(TRIALS, raptor=raptor).summary()["mean"]
        assert vec_mean == pytest.approx(scalar_mean, rel=0.08), (
            f"raptor={raptor}: scalar {scalar_mean:.0f}ms "
            f"vs vector {vec_mean:.0f}ms")


def test_keygen_ratio_agrees_with_scalar_and_paper():
    vec = VectorFlightSim(keygen_vector(), num_azs=3, flight=2, load="low",
                          seed=0)
    pair = vec.run_pair(TRIALS)
    # paper Table 7 ratio 0.647, theory 2/3; open-loop sits just below
    assert pair["mean_ratio"] == pytest.approx(0.647, abs=0.06)


@pytest.mark.parametrize("load,tol_mid,tol_tail", [
    ("medium", 0.08, 0.12),
    # the scalar queue at high load has heavy-tailed busy periods; its own
    # mean moves ~7% between 900s windows, so the band is wider
    ("high", 0.12, 0.20),
])
def test_closed_loop_agrees_at_load_with_tails(load, tol_mid, tol_tail):
    """Medium/high-load agreement incl. p50/p90/p99, not just means —
    possible now that the vectorized sim queues (sim/vector_queue.py)."""
    from repro.sim.vector_queue import QueueFlightSim, keygen_queue
    jobs = scalar_run(keygen_workload, raptor=True, seed=7, load=load)
    resp = np.array([j.response for j in jobs])
    vec = QueueFlightSim(keygen_queue(), load=load, seed=0, **HA)
    vs = vec.run(2048, 16, raptor=True).summary()
    for key, scal in (("mean", resp.mean()),
                      ("median", np.percentile(resp, 50)),
                      ("p90", np.percentile(resp, 90))):
        assert vs[key] == pytest.approx(scal, rel=tol_mid), (
            f"{load}/{key}: scalar {scal:.0f}ms vs vector {vs[key]:.0f}ms")
    assert vs["p99"] == pytest.approx(np.percentile(resp, 99),
                                      rel=tol_tail), load


def test_fail_rate_agrees_with_scalar():
    vec = VectorFlightSim(reliability_vector(2, 0.3), num_azs=3, flight=2,
                          load="low", seed=0)
    for raptor in (False, True):
        jobs = scalar_run(lambda: reliability_workload(2, 0.3),
                          raptor=raptor, seed=5, duration_s=900.0)
        scalar_fail = float(np.mean([not j.ok for j in jobs]))
        vec_fail = vec.run(TRIALS, raptor=raptor).fail_rate()
        assert vec_fail == pytest.approx(scalar_fail, abs=0.03), (
            f"raptor={raptor}: scalar {scalar_fail:.3f} "
            f"vs vector {vec_fail:.3f}")


# ------------------------------------------------------------------
# order-statistics theory (on-device reductions)
# ------------------------------------------------------------------

def test_rho_zero_matches_exponential_prediction():
    """Fully independent exp tasks: the §4.2.1 2*E[min]/E[max] ratio."""
    sim = VectorFlightSim(exponential_vector(2, 1000.0), num_azs=3,
                          flight=2, rho=0.0, stream_latency_ms=0.0, seed=0)
    pair = sim.run_pair(TRIALS)
    assert pair["mean_ratio"] == pytest.approx(A.response_ratio_paper(),
                                               abs=0.05)


def test_failure_matches_exact_form():
    """Event replay and the closed-form 1-(1-p^F)^K must agree."""
    for n_tasks, p in ((2, 0.3), (4, 0.2)):
        sim = VectorFlightSim(reliability_vector(n_tasks, p), num_azs=3,
                              flight=n_tasks, seed=0)
        res = sim.run(TRIALS, raptor=True)
        assert res.fail_rate() == pytest.approx(
            A.raptor_failure_exact(p, n_tasks), abs=0.02)
        # the on-device draw reduction must match the replay near-exactly:
        # a job fails iff some task's every attempt errored
        assert res.fail_rate() == pytest.approx(res.theory_fail_rate(),
                                                abs=0.005)
        stock = sim.run(TRIALS, raptor=False)
        assert stock.fail_rate() == pytest.approx(
            A.forkjoin_failure(p, n_tasks), abs=0.02)


def test_flight_trial_tight_event_budget_exact():
    """With fail_prob = 0 every race event completes a DISTINCT task
    (success broadcasts preempt peers mid-that-task), so K scan trips
    replay the race exactly like the conservative F*K budget — the
    hottest-loop reduction the blocked engines run on.  Covers F > K
    (duplicate first tasks: the slower twin is preempted, no event)."""
    import jax
    import jax.numpy as jnp
    from repro.sim.vector import _flight_trial
    rng = np.random.default_rng(11)
    for F, K in ((2, 2), (3, 5), (6, 2), (4, 4)):
        seq = jnp.array([np.roll(np.arange(K), -(m % K)) for m in range(F)])
        fail = jnp.zeros((F, K), dtype=bool)
        full = jax.jit(lambda z, tj, seq=seq: _flight_trial(
            z, jnp.zeros_like(z, dtype=bool), tj, seq, 0.5))
        tight = jax.jit(lambda z, tj, seq=seq, K=K: _flight_trial(
            z, jnp.zeros_like(z, dtype=bool), tj, seq, 0.5, num_events=K))
        for _ in range(25):
            z = jnp.array(rng.exponential(700.0, (F, K)).astype(np.float32))
            tj = jnp.array(rng.exponential(15.0, (F,)).astype(np.float32))
            t0, ok0 = full(z, tj)
            t1, ok1 = tight(z, tj)
            assert bool(ok0) and bool(ok1)
            assert float(t0) == float(t1), (F, K)


def test_scale_effect_monotone():
    """1 AZ: correlated replicas, ~no win.  3+ AZs: the full E[min] win."""
    ratios = {}
    for num_azs in (1, 3):
        sim = VectorFlightSim(keygen_vector(), num_azs=num_azs, flight=2,
                              seed=0)
        ratios[num_azs] = sim.run_pair(TRIALS)["mean_ratio"]
    assert ratios[1] > 0.90, f"1-AZ should show ~no benefit: {ratios[1]}"
    assert ratios[3] < 0.75, f"3-AZ should show the ~2/3 win: {ratios[3]}"


def test_random_sequences_keep_the_plateau():
    """ROADMAP paper-gap probe: at F=16, K=2 the measured ratio plateaus
    far above the K*E[min_F]/E[max_K] prediction.  Randomised member
    orders must not resolve it — only ~F/K members race any one task
    under EITHER ordering, so the plateau is structural, not an artefact
    of cyclic-shift duplication."""
    theory = A.raptor_speedup_prediction(num_tasks=2, flight=16)
    ratios = {}
    for mode in ("cyclic", "random"):
        sim = VectorFlightSim(exponential_vector(2, 1000.0), num_azs=8,
                              flight=16, rho=0.95, seed=0, sequences=mode)
        ratios[mode] = sim.run_pair(20_000)["mean_ratio"]
    assert ratios["random"] == pytest.approx(ratios["cyclic"], abs=0.05)
    assert ratios["random"] > 1.5 * theory, (
        f"plateau unexpectedly resolved: {ratios} vs theory {theory:.3f}")


def test_flight_plateau_matches_corrected_formula():
    """EXPERIMENTS.md: the F=16, K=2 plateau is predicted by the corrected
    effective-race-width form K*E[min_{F/K}]/E[max_K] (~0.167), not the
    paper's K*E[min_F]/E[max_K] (~0.083).  Sweep-driven: the measurement
    is the same sweep_pairs point sweep_scale() records."""
    wl = exponential_vector(2, 1000.0)
    measured = sweep_pairs(wl, [dict(flight=16, num_azs=8)],
                           trials=20_000, seed=0)[0]["mean_ratio"]
    corrected = A.raptor_plateau_prediction(num_tasks=2, flight=16)
    paper = A.raptor_speedup_prediction(num_tasks=2, flight=16)
    # measured 0.198: within tolerance of the corrected 0.167...
    assert measured == pytest.approx(corrected, rel=0.25), (
        f"measured {measured:.3f} vs corrected {corrected:.3f}")
    # ...while the paper's lockstep form is rejected (off by >2x and
    # strictly farther from the measurement than the corrected form)
    assert measured > 2.0 * paper
    assert abs(measured - corrected) < abs(measured - paper)


def test_sweep_pairs_matches_single_config():
    """Pad-and-mask batching is pure vectorization: an unpadded config in
    a sweep must reproduce the per-config VectorFlightSim numbers."""
    wl = exponential_vector(2, 1000.0)
    sweep = sweep_pairs(wl, [dict(flight=2, num_azs=3)], trials=20_000,
                        seed=0)[0]
    solo = VectorFlightSim(wl, num_azs=3, flight=2, seed=0).run_pair(20_000)
    assert sweep["raptor"]["mean"] == pytest.approx(
        solo["raptor"]["mean"], rel=1e-4)
    assert sweep["mean_ratio"] == pytest.approx(solo["mean_ratio"],
                                                abs=1e-3)


def test_sweep_pairs_mixed_ha_uses_right_overhead_row():
    """A 1-AZ config batched with HA configs must keep its own Table-6
    overhead regime (keyed by (ha, load), not load alone)."""
    wl = exponential_vector(2, 1000.0)
    mixed = sweep_pairs(wl, [dict(flight=4, num_azs=1),
                             dict(flight=4, num_azs=8)], trials=20_000,
                        seed=0)[0]
    solo = VectorFlightSim(wl, num_azs=1, flight=4,
                           seed=0).run_pair(20_000)
    assert mixed["mean_ratio"] == pytest.approx(solo["mean_ratio"],
                                                abs=0.02)
    assert mixed["stock"]["mean"] == pytest.approx(solo["stock"]["mean"],
                                                   rel=0.02)


def test_padded_failure_draws_stay_consistent():
    """Padded members must be neutral in the all-attempts-errored
    reduction: theory_fail_rate (recomputed from the raw draws) has to
    keep matching the event replay for a padded fail_prob>0 config."""
    import jax
    from repro.sim.vector import VectorResult, _raptor_sweep_core
    t, ok, fail = jax.jit(functools.partial(
        _raptor_sweep_core, trials=20_000, flight_max=4, num_tasks=2,
        azs_max=3, dist="lognorm", fail_prob=0.3))(
            jax.random.PRNGKey(1), 3, 3, 0.95, 100.0, 0.0, 0.05, 0.5, 0.5,
            2.2, 0.4)
    res = VectorResult(t, ok, fail, True)
    exact = A.raptor_failure_exact(0.3, 2, flight=3)
    assert res.fail_rate() == pytest.approx(exact, abs=0.02)
    assert res.theory_fail_rate() == pytest.approx(res.fail_rate(),
                                                   abs=0.005)


def test_sweep_pairs_padding_is_neutral():
    """A flight-2 config padded into a flight-16 bucket must agree with
    its unpadded run statistically (same model, masked members)."""
    wl = exponential_vector(2, 1000.0)
    res = sweep_pairs(wl, [dict(flight=2, num_azs=3),
                           dict(flight=16, num_azs=3)], trials=20_000,
                      seed=0)
    solo = VectorFlightSim(wl, num_azs=3, flight=2, seed=0).run_pair(20_000)
    assert res[0]["mean_ratio"] == pytest.approx(solo["mean_ratio"],
                                                 abs=0.02)


def test_summaries_condition_on_success():
    """fail_prob > 0: failed jobs' failure-*detection* times must not leak
    into the delay summaries (they used to drag the raptor mean/tails);
    they are accounted in fail_rate / n_failed instead."""
    sim = VectorFlightSim(reliability_vector(2, 0.3), num_azs=3, flight=2,
                          load="low", seed=0)
    res = sim.run(20_000, raptor=True)
    s = res.summary()
    resp = np.array(res.response_ms)
    ok = np.array(res.ok, dtype=bool)
    assert s["n"] == int(ok.sum())
    assert s["n_failed"] == int((~ok).sum()) and s["n_failed"] > 1000
    assert s["n"] + s["n_failed"] == resp.size
    assert s["mean"] == pytest.approx(float(resp[ok].mean()), rel=1e-5)
    # the bias this fix removes: failure-detection times ARE different
    # from success delays, so the unconditioned mean was wrong
    assert float(resp.mean()) != pytest.approx(s["mean"], rel=0.02)


def test_summarize_batch_matches_host():
    rng = np.random.default_rng(0)
    x = rng.exponential(100.0, size=5000)
    host = A.summarize(x)
    dev = {k: float(v) for k, v in A.summarize_batch(x).items()}
    for key in ("mean", "median", "p90", "p99"):
        assert dev[key] == pytest.approx(host[key], rel=2e-3), key
    assert dev["scv"] == pytest.approx(host["scv"], rel=1e-2)


def test_emp_order_stat_reductions():
    rng = np.random.default_rng(1)
    z = rng.exponential(1.0, size=(200_000, 4))
    assert float(A.emp_min_mean(z)) == pytest.approx(A.e_min_exp(4),
                                                     rel=0.02)
    assert float(A.emp_max_mean(z)) == pytest.approx(A.e_max_exp(4),
                                                     rel=0.02)
