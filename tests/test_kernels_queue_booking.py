"""Queue-booking Pallas kernel vs the sequential best-fit oracle.

Runs in interpret mode so the kernel tier is exercised on CPU-only CI
(ci.yml runs this file explicitly); the booking discipline itself is the
one the closed-loop stock engine replays, so parity here is parity with
the engine's oracle path (``scan_core.bestfit_book_step``).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    import hypothesis
    import hypothesis.strategies as st
except ModuleNotFoundError:  # bare env: property tests skip, rest still run
    from _hypothesis_compat import hypothesis, st

from repro.kernels.queue_booking.ops import book_stream
from repro.kernels.queue_booking.ref import book_stream_ref


def make(seed, T, N, W, util=0.8, dead_tail=0):
    rng = np.random.default_rng(seed)
    ready = np.sort(rng.uniform(0, N * 100 / (W * util), (T, N)),
                    axis=1).astype(np.float32)
    if dead_tail:
        ready[:, N - dead_tail:] = np.inf
    service = rng.exponential(100.0, (T, N)).astype(np.float32)
    wf0 = rng.uniform(0, 300.0, (T, W)).astype(np.float32)
    return jnp.asarray(ready), jnp.asarray(service), jnp.asarray(wf0)


CASES = [
    # (T, N, W, block, dead_tail)
    (2, 128, 15, 64, 0),
    (4, 200, 15, 64, 30),     # ragged stream: padded up + dead events
    (1, 96, 4, 16, 0),        # tiny pool
    (3, 256, 31, 128, 10),
]


@pytest.mark.parametrize("T,N,W,block,dead", CASES)
def test_kernel_matches_ref(T, N, W, block, dead):
    ready, service, wf0 = make(0, T, N, W, dead_tail=dead)
    fin, start, worker, wf = book_stream(ready, service, wf0, block=block,
                                         interpret=True)
    rfin, rstart, rworker, rwf = book_stream_ref(ready, service, wf0)
    np.testing.assert_array_equal(np.asarray(fin), np.asarray(rfin))
    np.testing.assert_array_equal(np.asarray(start), np.asarray(rstart))
    np.testing.assert_array_equal(np.asarray(worker), np.asarray(rworker))
    np.testing.assert_array_equal(np.asarray(wf), np.asarray(rwf))


def test_kernel_block_size_invariance():
    """The block size only chunks the VMEM-resident resolution; the
    schedule must be identical for any block."""
    ready, service, wf0 = make(1, 2, 192, 15)
    base = book_stream(ready, service, wf0, block=1, interpret=True)
    for block in (16, 64, 192):
        out = book_stream(ready, service, wf0, block=block, interpret=True)
        for a, b in zip(base, out):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_kernel_state_carries_between_blocks():
    """Bookings in an early block must constrain later blocks: zeroing the
    first block's service times frees workers earlier and must change
    later finish times (the W-vector actually crosses the block edge)."""
    ready, service, wf0 = make(2, 1, 128, 4, util=1.2)
    fin1, *_ = book_stream(ready, service, wf0, block=32, interpret=True)
    service2 = service.at[:, :32].set(0.0)
    fin2, *_ = book_stream(ready, service2, wf0, block=32, interpret=True)
    assert not np.array_equal(np.asarray(fin1[:, 64:]),
                              np.asarray(fin2[:, 64:]))


def test_dead_events_book_nothing():
    """ready=inf events (stream padding / unmaterialized fixed-point
    slots) must leave the pool untouched and report worker -1."""
    ready, service, wf0 = make(3, 2, 64, 8, dead_tail=20)
    fin, start, worker, wf = book_stream(ready, service, wf0, block=32,
                                         interpret=True)
    live = np.isfinite(np.asarray(ready))
    assert np.all(np.asarray(worker)[~live] == -1)
    assert np.all(np.isinf(np.asarray(fin)[~live]))
    # pool final state equals a replay of only the live prefix
    n_live = int(live[0].sum())
    _, _, _, wf_live = book_stream(ready[:, :n_live], service[:, :n_live],
                                   wf0, block=32, interpret=True)
    np.testing.assert_array_equal(np.asarray(wf), np.asarray(wf_live))


def test_engine_pallas_backend_matches_scan():
    """The in-engine route: QueueFlightSim(booking_backend="pallas") must
    replay the stock stream bit-for-bit like the jnp substrate."""
    from repro.sim.vector_queue import QueueFlightSim, wordcount_queue
    kw = dict(num_workers=15, num_azs=3, load="high", seed=0, block=64)
    a = QueueFlightSim(wordcount_queue(), **kw)
    b = QueueFlightSim(wordcount_queue(), booking_backend="pallas", **kw)
    np.testing.assert_array_equal(
        np.asarray(a.run(96, 2, raptor=False).response_ms),
        np.asarray(b.run(96, 2, raptor=False).response_ms))
    ta, tb = (s.trace_run(64, 2, raptor=False) for s in (a, b))
    for k in ("ready", "start", "fin", "worker"):
        np.testing.assert_array_equal(ta[k], tb[k])


@hypothesis.given(seed=st.integers(0, 1000), W=st.sampled_from([2, 7, 15]),
                  block=st.sampled_from([8, 32, 64]),
                  util=st.sampled_from([0.4, 0.9, 1.3]))
@hypothesis.settings(max_examples=10, deadline=None)
def test_kernel_property(seed, W, block, util):
    ready, service, wf0 = make(seed, 1, 96, W, util=util)
    fin, start, worker, wf = book_stream(ready, service, wf0, block=block,
                                         interpret=True)
    rfin, rstart, rworker, rwf = book_stream_ref(ready, service, wf0)
    np.testing.assert_array_equal(np.asarray(fin), np.asarray(rfin))
    np.testing.assert_array_equal(np.asarray(worker), np.asarray(rworker))
    np.testing.assert_array_equal(np.asarray(wf), np.asarray(rwf))
